//! SVRG (Johnson & Zhang 2013) and pwSVRG — preconditioned SVRG, the
//! high-precision stochastic baseline ("Preconditioning + SVRG" in
//! Table 1 / the pwSVRG curves in Figures 2-5).
//!
//! Epoch structure: at snapshot x~, compute the full gradient mu_g =
//! 2 A^T (A x~ - b); inner steps sample a row block tau and use the
//! variance-reduced direction
//!     v = g_tau(x) - g_tau(x~) + mu_g.
//! pwSVRG additionally applies the sketch-QR preconditioner R^{-1}R^{-T}
//! to every direction, which flattens kappa and is what makes SVRG usable
//! at all on the kappa = 1e8 datasets (the paper notes plain SVRG performs
//! poorly there, which the solver_convergence tests reproduce).

use super::driver::{drive, SolveSession, StepRule};
use super::{timed, Solver, SolveReport, SolverOpts};
use crate::backend::Backend;
use crate::constraints::ConstraintSet;
use crate::data::Dataset;
use crate::linalg::{blas, Mat};
use crate::precond::PrecondArtifact;
use crate::prox::metric::MetricProjector;
use anyhow::Result;
use std::sync::Arc;

/// SVRG / pwSVRG — (preconditioned) variance-reduced SGD, the
/// high-precision stochastic baseline.
pub struct Svrg {
    /// Apply the sketch-QR preconditioner to every direction (pwSVRG).
    pub preconditioned: bool,
}

/// (pw)SVRG as a step rule: `pre_chunk` takes the epoch snapshot + full
/// gradient on the solve clock (recorded as a 0-iteration trace point, as
/// before), inner chunks apply the variance-reduced direction, optionally
/// through the shared step-1 artifact in pw mode.
#[derive(Default)]
struct SvrgRule {
    preconditioned: bool,
    art: Option<Arc<PrecondArtifact>>,
    metric: Option<Arc<MetricProjector>>,
    eta: f64,
    scale: f64,
    m_inner: usize,
    r: usize,
    n: usize,
    x: Vec<f64>,
    snapshot: Vec<f64>,
    mu_g: Vec<f64>,
    done: usize,
    mbuf: Mat,
    vbuf: Vec<f64>,
}

impl StepRule for SvrgRule {
    fn name(&self) -> &'static str {
        if self.preconditioned {
            "pwsvrg"
        } else {
            "svrg"
        }
    }

    fn setup(&mut self, sess: &mut SolveSession) -> Result<()> {
        if self.preconditioned {
            let art = sess.precond(false)?;
            self.metric = sess.metric(&art);
            self.art = Some(art);
        }
        Ok(())
    }

    fn init(&mut self, sess: &mut SolveSession, x0: &[f64], _f0: f64) -> Result<()> {
        let (n, d) = (sess.ds.n(), sess.ds.d());
        let r = sess.opts.batch_size.max(1);
        // step size: preconditioned problem is ~2-smooth => 0.1 stable;
        // raw problem must scale by the (unknown) smoothness — use the row
        // moment bound like plain SGD (a shard-streaming scan on disk).
        self.eta = match sess.opts.eta {
            Some(e) => e,
            None if self.preconditioned => 0.1,
            None => {
                let row_ms: f64 = sess.ds.try_row_mean_sq()?;
                0.05 / (2.0 * n as f64 * row_ms.max(1e-300))
            }
        };
        // epoch length: 2n/r inner steps (standard SVRG choice)
        self.m_inner = (2 * n / r).clamp(16, 20_000);
        self.scale = 2.0 * n as f64 / r as f64;
        self.r = r;
        self.n = n;
        self.x = x0.to_vec();
        self.done = self.m_inner; // force a snapshot on the first chunk
        self.mbuf = Mat::zeros(r, d);
        self.vbuf = vec![0.0; r];
        Ok(())
    }

    fn pre_chunk(&mut self, sess: &mut SolveSession, _f: f64) -> Result<Option<f64>> {
        if self.done < self.m_inner {
            return Ok(None); // mid-epoch
        }
        // snapshot + full gradient (counted as solve time); the session
        // routes O(nnz) on sparse datasets, backend-dispatched on dense
        self.snapshot = self.x.clone();
        let (mu_g, snap_secs) = timed(|| sess.full_grad(&self.snapshot));
        self.mu_g = mu_g?;
        self.done = 0;
        Ok(Some(snap_secs))
    }

    fn chunk_len(&self, sess: &SolveSession, _f: f64) -> usize {
        sess.opts.chunk.min(self.m_inner - self.done)
    }

    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()> {
        let d = self.x.len();
        let ds = sess.ds;
        for _ in 0..t {
            let idx = sess.rng.indices(self.r, self.n);
            let (g_x, g_s) = if let Some(od) = ds.on_disk() {
                // on-disk: both gradients read the same sampled rows through
                // the shard cache (the second gather is a cache hit)
                (
                    od.batch_grad(&idx, &ds.b, &self.x, self.scale)?,
                    od.batch_grad(&idx, &ds.b, &self.snapshot, self.scale)?,
                )
            } else {
                match ds.csr() {
                    // sparse row-gather variance-reduced pair: both gradients
                    // read the same sampled rows in O(nnz(batch))
                    Some(csr) => (
                        csr.batch_grad(&idx, &ds.b, &self.x, self.scale),
                        csr.batch_grad(&idx, &ds.b, &self.snapshot, self.scale),
                    ),
                    None => {
                        let a = ds.dense_if_ready().expect("dense dataset");
                        for (row, &i) in idx.iter().enumerate() {
                            self.mbuf.row_mut(row).copy_from_slice(a.row(i));
                            self.vbuf[row] = ds.b[i];
                        }
                        (
                            blas::fused_grad(&self.mbuf, &self.vbuf, &self.x, self.scale),
                            blas::fused_grad(&self.mbuf, &self.vbuf, &self.snapshot, self.scale),
                        )
                    }
                }
            };
            let mut v: Vec<f64> = (0..d).map(|j| g_x[j] - g_s[j] + self.mu_g[j]).collect();
            if let Some(art) = &self.art {
                v = blas::gemv(&art.pinv, &v);
            }
            for (xi, vi) in self.x.iter_mut().zip(&v) {
                *xi -= self.eta * vi;
            }
            match self.metric.as_deref() {
                Some(m) => self.x = m.project(&self.x, sess.opts.constraint.as_ref()),
                None => sess.opts.constraint.project(&mut self.x),
            }
        }
        self.done += t;
        Ok(())
    }

    fn eval_x(&self, _sess: &SolveSession) -> Vec<f64> {
        self.x.clone()
    }
}

impl Solver for Svrg {
    fn name(&self) -> &'static str {
        if self.preconditioned {
            "pwsvrg"
        } else {
            "svrg"
        }
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport> {
        let mut rule = SvrgRule {
            preconditioned: self.preconditioned,
            ..Default::default()
        };
        drive(&mut rule, backend, ds, opts)
    }

    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        Some(Box::new(SvrgRule {
            preconditioned: self.preconditioned,
            ..SvrgRule::default()
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact::ground_truth;
    use crate::util::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn svrg_reaches_high_precision_on_well_conditioned() {
        let ds = dataset(1024, 6, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 8;
        opts.max_iters = 30_000;
        opts.chunk = 500;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(1e-9 * gt.f_star);
        let rep = Svrg { preconditioned: false }.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 1e-6, "svrg rel {rel}");
    }

    #[test]
    fn pwsvrg_beats_svrg_on_ill_conditioned() {
        let spec = crate::data::synthetic::SynSpec {
            name: "ill".into(),
            n: 1024,
            d: 6,
            kappa: 1e5,
            noise: 0.05,
            signal_scale: 1.0,
        };
        let ds = crate::data::synthetic::generate(&spec, &mut Rng::new(2));
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 8;
        opts.max_iters = 4000;
        opts.chunk = 500;
        let plain = Svrg { preconditioned: false }.solve(&Backend::native(), &ds, &opts).unwrap();
        let pw = Svrg { preconditioned: true }.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel_plain = (plain.f_final - gt.f_star) / gt.f_star.max(1e-12);
        let rel_pw = (pw.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(
            rel_pw < 0.1 * rel_plain.max(1e-12),
            "pwsvrg {rel_pw} vs svrg {rel_plain}"
        );
    }

    #[test]
    fn constrained_feasibility() {
        let ds = dataset(512, 5, 3);
        let cons = crate::constraints::l2_ball(0.3);
        let mut opts = SolverOpts::default();
        opts.constraint = cons.clone();
        opts.max_iters = 1000;
        opts.chunk = 200;
        let rep = Svrg { preconditioned: true }.solve(&Backend::native(), &ds, &opts).unwrap();
        assert!(cons.contains(&rep.x, 1e-9));
    }
}
