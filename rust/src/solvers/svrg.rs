//! SVRG (Johnson & Zhang 2013) and pwSVRG — preconditioned SVRG, the
//! high-precision stochastic baseline ("Preconditioning + SVRG" in
//! Table 1 / the pwSVRG curves in Figures 2-5).
//!
//! Epoch structure: at snapshot x~, compute the full gradient mu_g =
//! 2 A^T (A x~ - b); inner steps sample a row block tau and use the
//! variance-reduced direction
//!     v = g_tau(x) - g_tau(x~) + mu_g.
//! pwSVRG additionally applies the sketch-QR preconditioner R^{-1}R^{-T}
//! to every direction, which flattens kappa and is what makes SVRG usable
//! at all on the kappa = 1e8 datasets (the paper notes plain SVRG performs
//! poorly there, which the solver_convergence tests reproduce).

use super::{timed, Solver, SolveReport, SolverOpts, TraceRecorder};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::linalg::{blas, Mat};
use crate::precond::precondition_with;
use crate::sketch::default_sketch_size_for;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

pub struct Svrg {
    pub preconditioned: bool,
}

impl Solver for Svrg {
    fn name(&self) -> &'static str {
        if self.preconditioned {
            "pwsvrg"
        } else {
            "svrg"
        }
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> SolveReport {
        let mut rng = Rng::new(opts.seed);
        let n = ds.n();
        let d = ds.d();
        let r = opts.batch_size.max(1);

        // ---- setup (preconditioner only in pw mode) ------------------------
        let setup_timer = Timer::start();
        let (pinv, metric) = if self.preconditioned {
            let s = opts
                .sketch_size
                .unwrap_or_else(|| default_sketch_size_for(n, d, opts.sketch));
            let pre =
                precondition_with(backend, &ds.a, opts.sketch, s, &mut rng, opts.block_rows);
            let metric = match opts.constraint {
                crate::prox::Constraint::Unconstrained => None,
                _ => Some(crate::prox::metric::MetricProjector::from_r(&pre.r)),
            };
            (Some(pre.pinv), metric)
        } else {
            (None, None)
        };
        let setup_secs = setup_timer.secs();

        let x0 = vec![0.0; d];
        let f0 = backend.residual_sq(&ds.a, &ds.b, &x0);
        // step size: preconditioned problem is ~2-smooth => 0.1 stable;
        // raw problem must scale by the (unknown) smoothness — use the row
        // moment bound like plain SGD.
        let eta = opts.eta.unwrap_or_else(|| {
            if self.preconditioned {
                0.1
            } else {
                let row_ms: f64 =
                    ds.a.data.iter().map(|v| v * v).sum::<f64>() / n as f64;
                0.05 / (2.0 * n as f64 * row_ms.max(1e-300))
            }
        });
        // epoch length: 2n/r inner steps (standard SVRG choice)
        let m_inner = (2 * n / r).clamp(16, 20_000);
        let scale = 2.0 * n as f64 / r as f64;

        let mut rec = TraceRecorder::new(setup_secs, f0);
        let mut x = x0;
        let mut f = f0;
        let mut mbuf = Mat::zeros(r, d);
        let mut vbuf = vec![0.0; r];
        'outer: while !rec.should_stop(opts, f) {
            // snapshot + full gradient (counted as solve time)
            let snapshot = x.clone();
            let (mu_g, snap_secs) =
                timed(|| backend.full_grad(&ds.a, &ds.b, &snapshot));
            rec.record(0, snap_secs, f);
            let mut done = 0usize;
            while done < m_inner {
                let t_chunk = opts
                    .chunk
                    .min(m_inner - done)
                    .min(opts.max_iters.saturating_sub(rec.iters()))
                    .max(1);
                let (_, secs) = timed(|| {
                    for _ in 0..t_chunk {
                        let idx = rng.indices(r, n);
                        for (row, &i) in idx.iter().enumerate() {
                            mbuf.row_mut(row).copy_from_slice(ds.a.row(i));
                            vbuf[row] = ds.b[i];
                        }
                        let g_x = blas::fused_grad(&mbuf, &vbuf, &x, scale);
                        let g_s = blas::fused_grad(&mbuf, &vbuf, &snapshot, scale);
                        let mut v: Vec<f64> = (0..d)
                            .map(|j| g_x[j] - g_s[j] + mu_g[j])
                            .collect();
                        if let Some(p) = &pinv {
                            v = blas::gemv(p, &v);
                        }
                        for (xi, vi) in x.iter_mut().zip(&v) {
                            *xi -= eta * vi;
                        }
                        match &metric {
                            Some(m) => x = m.project(&x, &opts.constraint),
                            None => opts.constraint.project(&mut x),
                        }
                    }
                });
                done += t_chunk;
                f = backend.residual_sq(&ds.a, &ds.b, &x);
                rec.record(t_chunk, secs, f);
                if rec.should_stop(opts, f) {
                    break 'outer;
                }
            }
        }
        let name = if self.preconditioned { "pwsvrg" } else { "svrg" };
        rec.finish(name, x, f, setup_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact::ground_truth;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset {
            name: "t".into(),
            a,
            b,
            x_star_planted: Some(xt),
        }
    }

    #[test]
    fn svrg_reaches_high_precision_on_well_conditioned() {
        let ds = dataset(1024, 6, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 8;
        opts.max_iters = 30_000;
        opts.chunk = 500;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(1e-9 * gt.f_star);
        let rep = Svrg { preconditioned: false }.solve(&Backend::native(), &ds, &opts);
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 1e-6, "svrg rel {rel}");
    }

    #[test]
    fn pwsvrg_beats_svrg_on_ill_conditioned() {
        let spec = crate::data::synthetic::SynSpec {
            name: "ill".into(),
            n: 1024,
            d: 6,
            kappa: 1e5,
            noise: 0.05,
            signal_scale: 1.0,
        };
        let ds = crate::data::synthetic::generate(&spec, &mut Rng::new(2));
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 8;
        opts.max_iters = 4000;
        opts.chunk = 500;
        let plain = Svrg { preconditioned: false }.solve(&Backend::native(), &ds, &opts);
        let pw = Svrg { preconditioned: true }.solve(&Backend::native(), &ds, &opts);
        let rel_plain = (plain.f_final - gt.f_star) / gt.f_star.max(1e-12);
        let rel_pw = (pw.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(
            rel_pw < 0.1 * rel_plain.max(1e-12),
            "pwsvrg {rel_pw} vs svrg {rel_plain}"
        );
    }

    #[test]
    fn constrained_feasibility() {
        let ds = dataset(512, 5, 3);
        let cons = crate::prox::Constraint::L2Ball { radius: 0.3 };
        let mut opts = SolverOpts::default();
        opts.constraint = cons;
        opts.max_iters = 1000;
        opts.chunk = 200;
        let rep = Svrg { preconditioned: true }.solve(&Backend::native(), &ds, &opts);
        assert!(cons.contains(&rep.x, 1e-9));
    }
}
