//! The concrete constraint sets.
//!
//! The paper's four (unconstrained, l1/l2 ball, scalar box) reproduce the
//! pre-trait enum arithmetic bit for bit; the remaining sets open the
//! workload classes the enum could not express: probability-simplex
//! portfolio fits ([`Simplex`]), nonnegative least squares ([`NonNeg`]),
//! bound-constrained calibration with per-coordinate limits ([`CoordBox`]),
//! elastic-net-ball sparse recovery ([`ElasticNetBall`]), and equality
//! -constrained calibration ([`AffineEquality`]).
//!
//! Projection math lives in [`crate::prox`] (Euclidean) and
//! [`crate::prox::metric`] (R-metric primitives); this file wires each set
//! to its operators and documents the per-set complexity.

use super::ConstraintSet;
use crate::linalg::blas::{self, nrm2};
use crate::linalg::{qr, tri, Mat};
use crate::prox::metric::MetricProjector;
use crate::prox::{
    elastic_net_value, project_elastic_net, project_l1, project_l2, project_simplex,
};
use anyhow::{ensure, Result};
use std::fmt;

/// W = R^d — no projection, no diameter, PJRT-eligible.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Unconstrained;

impl ConstraintSet for Unconstrained {
    fn tag(&self) -> &'static str {
        "unc"
    }

    fn params(&self) -> String {
        String::new()
    }

    fn project(&self, _x: &mut [f64]) {}

    fn contains(&self, _x: &[f64], _tol: f64) -> bool {
        true
    }

    fn diameter(&self) -> Option<f64> {
        None
    }

    fn project_metric(&self, _metric: &MetricProjector, z: &[f64]) -> Vec<f64> {
        z.to_vec()
    }

    fn is_unconstrained(&self) -> bool {
        true
    }

    fn accel_eligible(&self) -> bool {
        true
    }
}

/// W = {x : ||x||_2 <= radius}. Euclidean projection is radial rescaling
/// (O(d)); the metric projection is the exact dual bisection
/// ([`MetricProjector::project_l2_ball`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct L2Ball {
    /// Ball radius (> 0).
    pub radius: f64,
}

impl ConstraintSet for L2Ball {
    fn tag(&self) -> &'static str {
        "l2"
    }

    fn params(&self) -> String {
        format!("radius={}", self.radius)
    }

    fn project(&self, x: &mut [f64]) {
        project_l2(x, self.radius)
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        nrm2(x) <= self.radius + tol
    }

    fn diameter(&self) -> Option<f64> {
        Some(self.radius / 2f64.sqrt())
    }

    fn project_metric(&self, metric: &MetricProjector, z: &[f64]) -> Vec<f64> {
        metric.project_l2_ball(z, self.radius)
    }

    fn radius(&self) -> f64 {
        self.radius
    }

    fn accel_eligible(&self) -> bool {
        true
    }
}

/// W = {x : ||x||_1 <= radius}. Euclidean projection is the O(d log d)
/// Duchi pivot ([`project_l1`]); the metric projection runs ADMM with the
/// l1 pivot as its Euclidean oracle (interior points short-circuit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct L1Ball {
    /// Ball radius (> 0).
    pub radius: f64,
}

impl ConstraintSet for L1Ball {
    fn tag(&self) -> &'static str {
        "l1"
    }

    fn params(&self) -> String {
        format!("radius={}", self.radius)
    }

    fn project(&self, x: &mut [f64]) {
        project_l1(x, self.radius)
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        x.iter().map(|v| v.abs()).sum::<f64>() <= self.radius + tol
    }

    fn diameter(&self) -> Option<f64> {
        Some(self.radius / 2f64.sqrt())
    }

    // project_metric: the inherited default (interior short-circuit + ADMM
    // around `project`) IS the pre-trait l1 metric path bit for bit — the
    // old code checked `l1 <= radius` (== `contains(z, 0.0)`) and ran ADMM
    // with the Duchi pivot as its oracle, exactly what the default does.

    fn radius(&self) -> f64 {
        self.radius
    }

    fn accel_eligible(&self) -> bool {
        true
    }
}

/// W = {x : lo <= x_i <= hi} with one scalar bound pair for every
/// coordinate — the legacy box. O(d) clamp; the metric projection is ADMM
/// with the clamp oracle (no interior short-circuit, preserving the
/// pre-trait arithmetic exactly).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScalarBox {
    /// Lower bound applied to every coordinate.
    pub lo: f64,
    /// Upper bound applied to every coordinate.
    pub hi: f64,
}

impl ConstraintSet for ScalarBox {
    fn tag(&self) -> &'static str {
        "box"
    }

    fn params(&self) -> String {
        format!("lo={} hi={}", self.lo, self.hi)
    }

    fn project(&self, x: &mut [f64]) {
        for v in x {
            *v = v.clamp(self.lo, self.hi);
        }
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        x.iter().all(|&v| v >= self.lo - tol && v <= self.hi + tol)
    }

    fn diameter(&self) -> Option<f64> {
        // LEGACY convention (bit-compat with the pre-trait enum): the
        // per-coordinate bound m/sqrt(2), NOT scaled by sqrt(d) — an
        // underestimate of the exact D_W that [`CoordBox`] implements. The
        // same geometric set therefore reports a smaller diameter (and a
        // smaller theory step) through `ScalarBox` than through a constant
        // `CoordBox`; callers who want the exact bound use the vector form.
        let m = self.lo.abs().max(self.hi.abs());
        Some(m / 2f64.sqrt())
    }

    fn project_metric(&self, metric: &MetricProjector, z: &[f64]) -> Vec<f64> {
        // box: coordinate-separable only in the Euclidean metric; use ADMM
        // with a clamp in place of the l1 projection
        let (lo, hi) = (self.lo, self.hi);
        metric.project_admm(z, |u| {
            for v in u.iter_mut() {
                *v = v.clamp(lo, hi);
            }
        })
    }
}

/// W = {x : x_i >= 0} — nonnegative least squares. O(d) clamp at zero;
/// unbounded, so no diameter term.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NonNeg;

impl ConstraintSet for NonNeg {
    fn tag(&self) -> &'static str {
        "nonneg"
    }

    fn params(&self) -> String {
        String::new()
    }

    fn project(&self, x: &mut [f64]) {
        for v in x {
            *v = v.max(0.0);
        }
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        x.iter().all(|&v| v >= -tol)
    }

    fn diameter(&self) -> Option<f64> {
        None
    }
}

/// W = {x : x_i >= 0, sum_i x_i = total} — the scaled probability simplex
/// (`total = 1` is the standard simplex of portfolio weights / mixture
/// coefficients). Euclidean projection is the O(d log d) sort-based pivot
/// ([`project_simplex`]); the metric path uses the inherited ADMM fallback
/// with that pivot as its oracle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Simplex {
    /// Coordinate sum (> 0); 1 for the standard probability simplex.
    pub total: f64,
}

impl ConstraintSet for Simplex {
    fn tag(&self) -> &'static str {
        "simplex"
    }

    fn params(&self) -> String {
        format!("total={}", self.total)
    }

    fn project(&self, x: &mut [f64]) {
        project_simplex(x, self.total)
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        x.iter().all(|&v| v >= -tol) && (x.iter().sum::<f64>() - self.total).abs() <= tol
    }

    fn diameter(&self) -> Option<f64> {
        // the simplex sits inside the l1 ball of radius `total`; use the
        // ball convention for the Theorem-2 term
        Some(self.total / 2f64.sqrt())
    }
}

/// W = {x : lo_i <= x_i <= hi_i} — per-coordinate bounds. O(d) clamp;
/// dimension-typed, so [`ConstraintSet::check_dim`] enforces that the bound
/// vectors match the dataset's `d`.
#[derive(Clone, Debug, PartialEq)]
pub struct CoordBox {
    /// Per-coordinate lower bounds (length d).
    pub lo: Vec<f64>,
    /// Per-coordinate upper bounds (length d).
    pub hi: Vec<f64>,
}

impl ConstraintSet for CoordBox {
    fn tag(&self) -> &'static str {
        "box"
    }

    fn params(&self) -> String {
        if self.lo.len() <= 4 {
            format!("lo={:?} hi={:?}", self.lo, self.hi)
        } else {
            // long vectors summarize as ranges — the bounds still reach
            // reports (the whole point of params over the old radius())
            let range = |v: &[f64]| {
                let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                format!("[{lo}..{hi}]")
            };
            format!(
                "d={} lo={} hi={}",
                self.lo.len(),
                range(&self.lo),
                range(&self.hi)
            )
        }
    }

    fn project(&self, x: &mut [f64]) {
        debug_assert_eq!(x.len(), self.lo.len(), "CoordBox dimension mismatch");
        for ((v, &lo), &hi) in x.iter_mut().zip(&self.lo).zip(&self.hi) {
            *v = v.clamp(lo, hi);
        }
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        x.len() == self.lo.len()
            && x.iter()
                .zip(&self.lo)
                .zip(&self.hi)
                .all(|((&v, &lo), &hi)| v >= lo - tol && v <= hi + tol)
    }

    fn diameter(&self) -> Option<f64> {
        // the exact Theorem-2 bound: max ||x||^2 over the box is
        // sum_i max(lo_i^2, hi_i^2), min >= 0. Deliberately NOT the legacy
        // per-coordinate convention `ScalarBox` keeps for bit-compat.
        let max_sq: f64 = self
            .lo
            .iter()
            .zip(&self.hi)
            .map(|(&lo, &hi)| (lo * lo).max(hi * hi))
            .sum();
        Some((0.5 * max_sq).sqrt())
    }

    fn check_dim(&self, d: usize) -> Result<()> {
        ensure!(
            self.lo.len() == d && self.hi.len() == d,
            "box bounds are {}-dimensional but the dataset has d={}",
            self.lo.len(),
            d
        );
        Ok(())
    }
}

/// W = {x : alpha ||x||_1 + (1 - alpha)/2 ||x||_2^2 <= radius} — the
/// elastic-net ball. Euclidean projection bisects the scalar dual
/// multiplier ([`project_elastic_net`], O(d) per bisection); the metric
/// path uses the inherited ADMM fallback. Degenerates to the l1 ball at
/// `alpha = 1` and to the l2 ball of radius `sqrt(2 radius)` at
/// `alpha = 0`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElasticNetBall {
    /// l1/l2 trade-off in [0, 1].
    pub alpha: f64,
    /// Sublevel value (> 0).
    pub radius: f64,
}

impl ElasticNetBall {
    /// The largest feasible ||x||_2: the positive root of
    /// (1-alpha)/2 rho^2 + alpha rho = radius (any x with ||x||_1 >= ||x||_2
    /// outside that l2 ball violates the constraint).
    fn l2_bound(&self) -> f64 {
        if self.alpha >= 1.0 {
            self.radius
        } else {
            let a = self.alpha;
            ((a * a + 2.0 * (1.0 - a) * self.radius).sqrt() - a) / (1.0 - a)
        }
    }
}

impl ConstraintSet for ElasticNetBall {
    fn tag(&self) -> &'static str {
        "enet"
    }

    fn params(&self) -> String {
        format!("alpha={} radius={}", self.alpha, self.radius)
    }

    fn project(&self, x: &mut [f64]) {
        project_elastic_net(x, self.alpha, self.radius)
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        elastic_net_value(x, self.alpha) <= self.radius + tol
    }

    fn diameter(&self) -> Option<f64> {
        Some(self.l2_bound() / 2f64.sqrt())
    }
}

/// W = {x : Cx = e} for a small full-row-rank C (k x d, k <= d) — equality
/// -constrained calibration (e.g. fixed totals, pinned coefficients).
///
/// Construction caches the thin QR of C^T once: with C^T = QR, the
/// Euclidean projection is the O(dk) affine map
/// `x* = (I - QQ^T) x + Q R^{-T} e` (the `Q R^{-T} e` shift is
/// precomputed). The metric projection overrides the ADMM fallback with the
/// exact KKT solve `x* = z - H^{-1} C^T lam`, where
/// `(C H^{-1} C^T) lam = Cz - e` is a k x k system assembled through
/// [`MetricProjector::h_inv_apply`].
#[derive(Clone)]
pub struct AffineEquality {
    c: Mat,
    e: Vec<f64>,
    /// Orthonormal basis of range(C^T) (d x k) from the cached QR.
    q: Mat,
    /// Precomputed Q R^{-T} e — the particular-solution shift.
    shift: Vec<f64>,
}

impl fmt::Debug for AffineEquality {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AffineEquality")
            .field("k", &self.c.rows)
            .field("d", &self.c.cols)
            .finish()
    }
}

impl AffineEquality {
    /// Build the set, caching the QR of C^T. Fails when the shape is
    /// degenerate (k = 0, k > d, |e| != k) or the rows of C are linearly
    /// dependent (a rank-deficient system has either redundant or
    /// inconsistent rows — reformulate with independent rows).
    pub fn new(c: Mat, e: Vec<f64>) -> Result<AffineEquality> {
        let (k, d) = (c.rows, c.cols);
        ensure!(k > 0 && d > 0, "affine constraint must be non-empty");
        ensure!(
            k <= d,
            "affine constraint has more rows (k={k}) than dimensions (d={d})"
        );
        ensure!(
            e.len() == k,
            "affine rhs has {} entries for {k} constraint rows",
            e.len()
        );
        let fact = qr::qr(&c.transpose());
        let q = fact.q.expect("qr with q");
        let max_diag = (0..k).map(|i| fact.r.at(i, i)).fold(0.0f64, f64::max);
        for i in 0..k {
            ensure!(
                fact.r.at(i, i) > 1e-12 * max_diag.max(1e-300),
                "rows of C are linearly dependent (pivot {i} of the QR of C^T vanished)"
            );
        }
        // shift = Q R^{-T} e (the minimum-norm solution of Cx = e)
        let shift = blas::gemv(&q, &tri::solve_upper_t(&fact.r, &e));
        Ok(AffineEquality { c, e, q, shift })
    }

    /// The constraint matrix C (k x d).
    pub fn matrix(&self) -> &Mat {
        &self.c
    }

    /// The right-hand side e (length k).
    pub fn rhs(&self) -> &[f64] {
        &self.e
    }
}

impl ConstraintSet for AffineEquality {
    fn tag(&self) -> &'static str {
        "affine"
    }

    fn params(&self) -> String {
        format!("k={} d={}", self.c.rows, self.c.cols)
    }

    fn project(&self, x: &mut [f64]) {
        debug_assert_eq!(x.len(), self.c.cols, "AffineEquality dimension mismatch");
        // x* = x - Q (Q^T x) + shift
        let qtx = blas::gemv_t(&self.q, x);
        let corr = blas::gemv(&self.q, &qtx);
        for ((v, ci), si) in x.iter_mut().zip(&corr).zip(&self.shift) {
            *v = *v - ci + si;
        }
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        x.len() == self.c.cols
            && (0..self.c.rows)
                .all(|i| (blas::dot(self.c.row(i), x) - self.e[i]).abs() <= tol)
    }

    fn diameter(&self) -> Option<f64> {
        None // affine subspaces are unbounded
    }

    fn project_metric(&self, metric: &MetricProjector, z: &[f64]) -> Vec<f64> {
        // exact KKT: x = z - H^{-1} C^T lam with (C H^{-1} C^T) lam = Cz - e
        let k = self.c.rows;
        let hic: Vec<Vec<f64>> = (0..k).map(|i| metric.h_inv_apply(self.c.row(i))).collect();
        let mut mkk = Mat::zeros(k, k);
        let mut rhs = vec![0.0; k];
        for i in 0..k {
            for j in 0..k {
                *mkk.at_mut(i, j) = blas::dot(self.c.row(i), &hic[j]);
            }
            rhs[i] = blas::dot(self.c.row(i), z) - self.e[i];
        }
        let lam = qr::lstsq(&mkk, &rhs);
        let mut x = z.to_vec();
        for (li, hi) in lam.iter().zip(&hic) {
            for (xj, hj) in x.iter_mut().zip(hi) {
                *xj -= li * hj;
            }
        }
        x
    }

    fn check_dim(&self, d: usize) -> Result<()> {
        ensure!(
            self.c.cols == d,
            "affine constraint is {}-dimensional but the dataset has d={}",
            self.c.cols,
            d
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn legacy_sets_match_pre_trait_behavior() {
        // box clamp + contains
        let c = ScalarBox { lo: -1.0, hi: 1.0 };
        let mut x = vec![-5.0, 0.5, 7.0];
        c.project(&mut x);
        assert_eq!(x, vec![-1.0, 0.5, 1.0]);
        assert!(c.contains(&x, 1e-12));
        // l2 dispatch + radius accessor
        let mut y = vec![3.0, 4.0];
        let l2 = L2Ball { radius: 1.0 };
        assert!(!l2.contains(&y, 0.0));
        l2.project(&mut y);
        assert!(l2.contains(&y, 1e-12));
        assert_eq!(l2.tag(), "l2");
        assert_eq!(ConstraintSet::radius(&l2), 1.0);
        // unconstrained is a no-op
        let u = Unconstrained;
        let mut z = vec![1e9];
        u.project(&mut z);
        assert_eq!(z, vec![1e9]);
        assert!(u.contains(&z, 0.0));
        // degenerate box pins every coordinate
        let pin = ScalarBox { lo: 0.7, hi: 0.7 };
        let mut w = vec![-3.0, 0.7, 12.0, 0.0];
        pin.project(&mut w);
        assert_eq!(w, vec![0.7; 4]);
    }

    #[test]
    fn legacy_diameters_unchanged() {
        assert_eq!(Unconstrained.diameter(), None);
        assert_eq!(L2Ball { radius: 2.0 }.diameter(), Some(2.0 / 2f64.sqrt()));
        assert_eq!(L1Ball { radius: 2.0 }.diameter(), Some(2.0 / 2f64.sqrt()));
        assert_eq!(
            ScalarBox { lo: -1.0, hi: 3.0 }.diameter(),
            Some(3.0 / 2f64.sqrt())
        );
    }

    #[test]
    fn nonneg_projects_and_reports() {
        let mut x = vec![-2.0, 0.0, 3.5];
        NonNeg.project(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 3.5]);
        assert!(NonNeg.contains(&x, 0.0));
        assert!(!NonNeg.contains(&[-0.1], 1e-3));
        assert!(NonNeg.contains(&[-0.1], 0.2));
        assert_eq!(NonNeg.diameter(), None);
    }

    #[test]
    fn simplex_set_projects_onto_simplex() {
        let s = Simplex { total: 1.0 };
        let mut x = vec![2.0, -1.0, 0.5];
        s.project(&mut x);
        assert!(s.contains(&x, 1e-12), "{x:?}");
        assert_eq!(s.diameter(), Some(1.0 / 2f64.sqrt()));
    }

    #[test]
    fn coord_box_clamps_per_coordinate_and_checks_dim() {
        let b = CoordBox {
            lo: vec![0.0, -1.0, 2.0],
            hi: vec![1.0, 1.0, 2.0],
        };
        let mut x = vec![-5.0, 0.5, 7.0];
        b.project(&mut x);
        assert_eq!(x, vec![0.0, 0.5, 2.0]);
        assert!(b.contains(&x, 0.0));
        assert!(!b.contains(&[0.0, 0.0], 1.0), "length mismatch is infeasible");
        assert!(b.check_dim(3).is_ok());
        assert!(b.check_dim(4).is_err());
        // diameter: sqrt(sum max(lo^2, hi^2) / 2)
        let want = ((1.0f64 + 1.0 + 4.0) / 2.0).sqrt();
        assert!((b.diameter().unwrap() - want).abs() < 1e-12);
    }

    #[test]
    fn elastic_net_ball_bounds_and_projects() {
        let e = ElasticNetBall {
            alpha: 0.5,
            radius: 1.0,
        };
        let mut x = vec![4.0, -3.0, 2.0];
        e.project(&mut x);
        assert!(e.contains(&x, 1e-9), "{x:?}");
        // l2_bound solves (1-a)/2 rho^2 + a rho = r
        let rho = e.l2_bound();
        assert!((0.25 * rho * rho + 0.5 * rho - 1.0).abs() < 1e-12);
        // alpha = 1 degenerates to the l1 radius
        let l1ish = ElasticNetBall {
            alpha: 1.0,
            radius: 2.0,
        };
        assert_eq!(l1ish.l2_bound(), 2.0);
    }

    #[test]
    fn affine_equality_projects_onto_the_subspace() {
        let mut rng = Rng::new(1);
        // 2 x 5 system with independent rows
        let c = Mat::gaussian(2, 5, &mut rng);
        let e = vec![1.0, -0.5];
        let set = AffineEquality::new(c.clone(), e.clone()).unwrap();
        for _ in 0..20 {
            let mut x = rng.gaussians(5);
            set.project(&mut x);
            assert!(set.contains(&x, 1e-9), "Cx != e after projection");
            // idempotent
            let once = x.clone();
            set.project(&mut x);
            for (a, b) in x.iter().zip(&once) {
                assert!((a - b).abs() < 1e-10);
            }
        }
        assert_eq!(set.tag(), "affine");
        assert_eq!(set.params(), "k=2 d=5");
        assert!(set.check_dim(5).is_ok());
        assert!(set.check_dim(6).is_err());
    }

    #[test]
    fn affine_equality_rejects_degenerate_systems() {
        let mut rng = Rng::new(2);
        // duplicate rows => rank deficient
        let row = rng.gaussians(4);
        let mut c = Mat::zeros(2, 4);
        c.row_mut(0).copy_from_slice(&row);
        c.row_mut(1).copy_from_slice(&row);
        assert!(AffineEquality::new(c, vec![1.0, 2.0]).is_err());
        // rhs length mismatch
        let ok = Mat::gaussian(2, 4, &mut rng);
        assert!(AffineEquality::new(ok.clone(), vec![1.0]).is_err());
        // more rows than dims
        let wide = Mat::gaussian(5, 3, &mut rng);
        assert!(AffineEquality::new(wide, vec![0.0; 5]).is_err());
    }

    #[test]
    fn affine_metric_projection_satisfies_kkt() {
        let mut rng = Rng::new(3);
        let c = Mat::gaussian(2, 6, &mut rng);
        let e = vec![0.7, -1.2];
        let set = AffineEquality::new(c.clone(), e.clone()).unwrap();
        // an ill-conditioned H
        let a = Mat::from_fn(60, 6, |_i, j| rng.gaussian() * 10f64.powi(j as i32));
        let r = qr::qr_r(&a);
        let m = MetricProjector::from_r(&r);
        let z = rng.gaussians(6);
        let x = set.project_metric(&m, &z);
        // feasibility
        assert!(set.contains(&x, 1e-7), "Cx != e after metric projection");
        // stationarity: H (x - z) must lie in range(C^T)
        let h = blas::gemm(&r.transpose(), &r);
        let diff = blas::sub(&x, &z);
        let grad = blas::gemv(&h, &diff);
        // residual of grad after projecting onto range(C^T) must vanish:
        // grad - Q Q^T grad == 0
        let qr_ct = qr::qr(&c.transpose());
        let q = qr_ct.q.unwrap();
        let qt = blas::gemv_t(&q, &grad);
        let back = blas::gemv(&q, &qt);
        let scale = 1.0 + blas::nrm2(&grad);
        for (g, b) in grad.iter().zip(&back) {
            assert!(
                (g - b).abs() < 1e-6 * scale,
                "gradient leaves range(C^T): {g} vs {b}"
            );
        }
    }
}
