//! The constraint subsystem: *which* convex set W a solve runs under.
//!
//! Every algorithm in this repository iterates `x <- Proj_W(x - eta g)`
//! over an arbitrary convex W — the projection oracle is the pluggable part
//! of the method (Pilanci & Wainwright's IHS and Cormode & Dickens' sketch
//! -and-project both stress exactly this). This module makes the oracle a
//! first-class extension point:
//!
//! * [`ConstraintSet`] — the trait every set implements: Euclidean
//!   projection, membership, the Theorem-2 diameter term, a wire tag and a
//!   parameter summary, plus the R-metric projection with a documented
//!   fallback (ADMM splitting around the set's own Euclidean oracle, see
//!   [`crate::prox::metric::MetricProjector::project_admm`]).
//! * [`sets`] — the concrete sets: the paper's four
//!   ([`Unconstrained`], [`L2Ball`], [`L1Ball`], [`ScalarBox`]) plus the
//!   probability [`Simplex`], the nonnegative orthant [`NonNeg`], the
//!   per-coordinate [`CoordBox`], the [`ElasticNetBall`], and
//!   [`AffineEquality`] (`Cx = e`, cached QR of C^T).
//! * [`spec`] — [`ConstraintSpec`]: the serde-friendly wire/CLI description
//!   (`"simplex"`, `{"box": {"lo": [...], "hi": [...]}}`, `"l1:0.5"`, ...)
//!   that [`crate::coordinator::JobRequest`] carries and builds into an
//!   `Arc<dyn ConstraintSet>` per job.
//!
//! The four legacy sets reproduce the pre-trait enum arithmetic bit for bit
//! (same projection functions in [`crate::prox`], same metric strategies in
//! [`crate::prox::metric`]) — the golden/replay suites pin this.

pub mod sets;
pub mod spec;

pub use sets::{
    AffineEquality, CoordBox, ElasticNetBall, L1Ball, L2Ball, NonNeg, ScalarBox, Simplex,
    Unconstrained,
};
pub use spec::ConstraintSpec;

use crate::prox::metric::MetricProjector;
use anyhow::Result;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// A shared, type-erased constraint set — what [`crate::solvers::SolverOpts`]
/// carries and every solver projects through.
pub type ConstraintRef = Arc<dyn ConstraintSet>;

/// A closed convex constraint set W with the oracles the solvers need.
///
/// Implementations must be cheap to share (`Send + Sync`, used behind
/// [`Arc`]) and deterministic: `project` may not consume randomness, since
/// it runs inside bit-replayed solve traces.
pub trait ConstraintSet: Send + Sync + fmt::Debug {
    /// Short stable tag ("unc", "l1", "box", "simplex", ...) — the op-key
    /// component for executor routing and the constraint field of
    /// [`crate::coordinator::JobResult`]. Must not encode parameters; those
    /// go in [`ConstraintSet::params`].
    fn tag(&self) -> &'static str;

    /// Human-readable parameter summary ("radius=0.5", "lo=-1 hi=1", "")
    /// used by reports and the CLI's constraint line. This replaces the old
    /// enum's `radius()` as the reporting surface — a box's bounds, a
    /// simplex's total, and an affine system's shape all survive into
    /// artifacts of the run instead of flattening to `0.0`.
    fn params(&self) -> String;

    /// Euclidean projection onto W, in place.
    fn project(&self, x: &mut [f64]);

    /// Membership test with absolute tolerance `tol`.
    fn contains(&self, x: &[f64], tol: f64) -> bool;

    /// Diameter term D_W = sqrt(max 0.5||x||^2 - min 0.5||x||^2) from
    /// Theorem 2, used by the theoretical step size. `None` for unbounded
    /// sets (unconstrained, orthants, affine subspaces) — callers fall back
    /// to an f(x0)-based surrogate.
    fn diameter(&self) -> Option<f64>;

    /// Projection onto W in the R-metric H = R^T R (the paper's Step-6
    /// quadratic subproblem).
    ///
    /// Default — **the documented Euclidean-oracle fallback**: interior
    /// points return unchanged, everything else runs
    /// [`MetricProjector::project_admm`], which reduces the metric
    /// projection to repeated *Euclidean* projections through
    /// [`ConstraintSet::project`] (with H = I it collapses to a single
    /// Euclidean projection). Correct for any closed convex set; sets with
    /// cheaper exact solutions override (l2 ball: dual bisection; affine
    /// equality: closed-form KKT; unconstrained: identity).
    fn project_metric(&self, metric: &MetricProjector, z: &[f64]) -> Vec<f64> {
        if self.contains(z, 0.0) {
            return z.to_vec();
        }
        metric.project_admm(z, |u| self.project(u))
    }

    /// Whether this is W = R^d. Fast-path guard: unconstrained solves skip
    /// the metric projector entirely.
    fn is_unconstrained(&self) -> bool {
        false
    }

    /// The ball-radius scalar the PJRT artifacts take as a runtime input.
    /// Only meaningful for the ball sets the artifacts implement (l1/l2);
    /// everything else reports `0.0` and is never routed to an accelerated
    /// executor (see [`ConstraintSet::accel_eligible`]). Reporting surfaces
    /// must use [`ConstraintSet::params`] instead.
    fn radius(&self) -> f64 {
        0.0
    }

    /// Whether an accelerated (PJRT) executor may run this set's projected
    /// steps. Only the Euclidean unc/l1/l2 projections exist as compiled
    /// artifacts; every other set — and any set under an active R-metric —
    /// stays on the native executor. Defaults to `false`, so new sets are
    /// automatically native-only.
    fn accel_eligible(&self) -> bool {
        false
    }

    /// Validate this set against the problem dimension `d` (vector-valued
    /// boxes and affine systems are dimension-typed; scalar sets accept any
    /// `d`). Called once per job by the coordinator before the first trial.
    fn check_dim(&self, d: usize) -> Result<()> {
        let _ = d;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// constructors
// ---------------------------------------------------------------------------

/// W = R^d.
pub fn unconstrained() -> ConstraintRef {
    Arc::new(Unconstrained)
}

/// W = {x : ||x||_1 <= radius}.
pub fn l1_ball(radius: f64) -> ConstraintRef {
    Arc::new(L1Ball { radius })
}

/// W = {x : ||x||_2 <= radius}.
pub fn l2_ball(radius: f64) -> ConstraintRef {
    Arc::new(L2Ball { radius })
}

/// W = {x : lo <= x_i <= hi for every i} (one scalar bound pair).
pub fn scalar_box(lo: f64, hi: f64) -> ConstraintRef {
    Arc::new(ScalarBox { lo, hi })
}

/// W = {x : x_i >= 0} — nonnegative least squares.
pub fn nonneg() -> ConstraintRef {
    Arc::new(NonNeg)
}

/// W = {x : x_i >= 0, sum_i x_i = total} — the scaled probability simplex
/// (portfolio weights, mixture fits; `total = 1` is the standard simplex).
pub fn simplex(total: f64) -> ConstraintRef {
    Arc::new(Simplex { total })
}

/// W = {x : lo_i <= x_i <= hi_i} with per-coordinate bounds.
pub fn coord_box(lo: Vec<f64>, hi: Vec<f64>) -> ConstraintRef {
    Arc::new(CoordBox { lo, hi })
}

/// W = {x : alpha ||x||_1 + (1 - alpha)/2 ||x||_2^2 <= radius} — the
/// elastic-net ball from the sparse-recovery literature.
pub fn elastic_net(alpha: f64, radius: f64) -> ConstraintRef {
    Arc::new(ElasticNetBall { alpha, radius })
}

/// W = {x : Cx = e} for a small full-row-rank C (k x d, k <= d) — equality
/// -constrained calibration. Fails if the rows of C are linearly dependent.
pub fn affine_eq(c: crate::linalg::Mat, e: Vec<f64>) -> Result<ConstraintRef> {
    Ok(Arc::new(AffineEquality::new(c, e)?))
}

// ---------------------------------------------------------------------------
// projection counter
// ---------------------------------------------------------------------------

/// A counting decorator around a [`ConstraintSet`]: delegates every oracle
/// call and counts the projections (Euclidean and metric), so the
/// coordinator can report a `projections` figure per job and the serve
/// metrics can aggregate projection throughput. No-op projections of the
/// unconstrained set are not counted.
#[derive(Debug)]
pub struct ProjectionCounter {
    inner: ConstraintRef,
    count: AtomicUsize,
}

impl ProjectionCounter {
    /// Wrap `inner` in a fresh counter.
    pub fn wrap(inner: ConstraintRef) -> Arc<ProjectionCounter> {
        Arc::new(ProjectionCounter {
            inner,
            count: AtomicUsize::new(0),
        })
    }

    /// Projections observed so far (Euclidean + metric).
    pub fn count(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    fn bump(&self) {
        if !self.inner.is_unconstrained() {
            self.count.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl ConstraintSet for ProjectionCounter {
    fn tag(&self) -> &'static str {
        self.inner.tag()
    }

    fn params(&self) -> String {
        self.inner.params()
    }

    fn project(&self, x: &mut [f64]) {
        self.bump();
        self.inner.project(x)
    }

    fn contains(&self, x: &[f64], tol: f64) -> bool {
        self.inner.contains(x, tol)
    }

    fn diameter(&self) -> Option<f64> {
        self.inner.diameter()
    }

    fn project_metric(&self, metric: &MetricProjector, z: &[f64]) -> Vec<f64> {
        self.bump();
        // delegate to the *inner* strategy (exact bisection / KKT / ADMM) —
        // the decorator must not downgrade a specialized metric projection
        // to the generic fallback
        self.inner.project_metric(metric, z)
    }

    fn is_unconstrained(&self) -> bool {
        self.inner.is_unconstrained()
    }

    fn radius(&self) -> f64 {
        self.inner.radius()
    }

    fn accel_eligible(&self) -> bool {
        self.inner.accel_eligible()
    }

    fn check_dim(&self, d: usize) -> Result<()> {
        self.inner.check_dim(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_expose_tags_and_params() {
        assert_eq!(unconstrained().tag(), "unc");
        assert_eq!(l1_ball(0.5).tag(), "l1");
        assert_eq!(l1_ball(0.5).params(), "radius=0.5");
        assert_eq!(l2_ball(2.0).params(), "radius=2");
        assert_eq!(scalar_box(-1.0, 1.0).tag(), "box");
        assert_eq!(scalar_box(-1.0, 1.0).params(), "lo=-1 hi=1");
        assert_eq!(nonneg().tag(), "nonneg");
        assert_eq!(simplex(1.0).tag(), "simplex");
        assert_eq!(simplex(2.0).params(), "total=2");
        assert_eq!(elastic_net(0.5, 1.0).tag(), "enet");
        assert_eq!(coord_box(vec![0.0], vec![1.0]).tag(), "box");
    }

    #[test]
    fn accel_eligibility_matches_the_artifact_surface() {
        assert!(unconstrained().accel_eligible());
        assert!(l1_ball(1.0).accel_eligible());
        assert!(l2_ball(1.0).accel_eligible());
        for cons in [
            scalar_box(-1.0, 1.0),
            nonneg(),
            simplex(1.0),
            elastic_net(0.5, 1.0),
            coord_box(vec![0.0], vec![1.0]),
        ] {
            assert!(!cons.accel_eligible(), "{} must be native-only", cons.tag());
        }
    }

    #[test]
    fn projection_counter_counts_and_delegates() {
        let counted = ProjectionCounter::wrap(l2_ball(1.0));
        let mut x = vec![3.0, 4.0];
        counted.project(&mut x);
        assert!((crate::linalg::blas::nrm2(&x) - 1.0).abs() < 1e-12);
        assert_eq!(counted.count(), 1);
        assert_eq!(counted.tag(), "l2");
        assert_eq!(counted.radius(), 1.0);
        assert!(counted.accel_eligible());
        assert!(counted.contains(&x, 1e-12));
        // the wrapper coerces to the shared trait object type
        let as_ref: ConstraintRef = counted.clone();
        let mut y = vec![0.1, 0.1];
        as_ref.project(&mut y);
        assert_eq!(counted.count(), 2);
    }

    #[test]
    fn projection_counter_ignores_unconstrained_noops() {
        let counted = ProjectionCounter::wrap(unconstrained());
        let mut x = vec![1e9];
        counted.project(&mut x);
        counted.project(&mut x);
        assert_eq!(counted.count(), 0);
        assert!(counted.is_unconstrained());
    }
}
