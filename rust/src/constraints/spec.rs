//! [`ConstraintSpec`] — the serde-friendly wire/CLI description of a
//! constraint set.
//!
//! A spec is what travels in a [`crate::coordinator::JobRequest`] (JSON
//! field `constraint`) and on the CLI (`--constraint`); the coordinator
//! resolves derived radii against the ground truth and calls
//! [`ConstraintSpec::build`] to obtain the `Arc<dyn ConstraintSet>` the
//! solvers project through.
//!
//! Accepted forms (every set has both a compact string and a JSON shape):
//!
//! | set            | string            | JSON                                          |
//! |----------------|-------------------|-----------------------------------------------|
//! | unconstrained  | `"unc"`           | `"unc"`                                       |
//! | l1 ball        | `"l1"`, `"l1:0.5"`| `{"l1": 0.5}` / `{"l1": {"radius": 0.5}}`     |
//! | l2 ball        | `"l2"`, `"l2:2"`  | `{"l2": 2}` / `{"l2": {"radius": 2}}`         |
//! | nonneg orthant | `"nonneg"`        | `"nonneg"`                                    |
//! | simplex        | `"simplex"`, `"simplex:2"` | `{"simplex": 2}` / `{"simplex": {"total": 2}}` |
//! | scalar box     | `"box:-1,1"`      | `{"box": {"lo": -1, "hi": 1}}`                |
//! | coord box      | —                 | `{"box": {"lo": [..], "hi": [..]}}`           |
//! | elastic net    | `"enet:0.5,1"`    | `{"elastic_net": {"alpha": 0.5, "radius": 1}}`|
//! | affine Cx = e  | —                 | `{"affine_eq": {"c": [[..],..], "e": [..]}}`  |
//!
//! A radius of 0 on the ball-like sets means "derive from the
//! unconstrained optimum" — the paper's protocol (l1/l2: the norm of x*,
//! elastic net: the penalty value at x*). Parsing is strict and errors
//! carry the offending path (`constraint.box.lo[2]: ...`), so a bad spec on
//! the serve socket comes back as a precise one-line error.

use super::ConstraintRef;
use crate::linalg::Mat;
use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Result};

/// A parsed, validated constraint description (see the module docs for the
/// accepted wire forms). `build` turns it into the runtime
/// [`super::ConstraintSet`]; until then it is plain data — comparable,
/// clonable, and serializable back to JSON.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum ConstraintSpec {
    /// W = R^d.
    #[default]
    Unconstrained,
    /// l1 ball; `radius = 0` derives from the unconstrained optimum.
    L1Ball {
        /// Ball radius (0 = derive).
        radius: f64,
    },
    /// l2 ball; `radius = 0` derives from the unconstrained optimum.
    L2Ball {
        /// Ball radius (0 = derive).
        radius: f64,
    },
    /// Nonnegative orthant.
    NonNeg,
    /// Scaled probability simplex.
    Simplex {
        /// Coordinate sum (> 0).
        total: f64,
    },
    /// One scalar bound pair for every coordinate.
    ScalarBox {
        /// Lower bound.
        lo: f64,
        /// Upper bound.
        hi: f64,
    },
    /// Per-coordinate bounds (dimension-typed; validated against the
    /// dataset's d at job admission).
    CoordBox {
        /// Per-coordinate lower bounds.
        lo: Vec<f64>,
        /// Per-coordinate upper bounds.
        hi: Vec<f64>,
    },
    /// Elastic-net ball; `radius = 0` derives from the unconstrained
    /// optimum (the penalty value at x*).
    ElasticNet {
        /// l1/l2 trade-off in [0, 1].
        alpha: f64,
        /// Sublevel value (0 = derive).
        radius: f64,
    },
    /// Affine equality Cx = e (row-major C).
    AffineEq {
        /// Constraint rows (k x d, row-major).
        c: Vec<Vec<f64>>,
        /// Right-hand side (length k).
        e: Vec<f64>,
    },
}

fn parse_pos(s: &str, what: &str) -> Result<f64> {
    let v: f64 = s
        .trim()
        .parse()
        .map_err(|_| anyhow!("constraint: {what} {s:?} is not a number"))?;
    ensure!(v.is_finite() && v > 0.0, "constraint: {what} must be positive, got {s}");
    Ok(v)
}

fn num_at(j: &Json, path: &str) -> Result<f64> {
    let v = j
        .as_f64()
        .ok_or_else(|| anyhow!("{path}: expected a number, got {j}"))?;
    ensure!(v.is_finite(), "{path}: must be finite");
    Ok(v)
}

fn vec_at(j: &Json, path: &str) -> Result<Vec<f64>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| anyhow!("{path}: expected an array of numbers, got {j}"))?;
    arr.iter()
        .enumerate()
        .map(|(i, v)| num_at(v, &format!("{path}[{i}]")))
        .collect()
}

impl ConstraintSpec {
    /// Parse the compact string form (see the module table). Strings
    /// beginning with `{` are parsed as the JSON form.
    pub fn parse_str(s: &str) -> Result<ConstraintSpec> {
        let t = s.trim();
        if t.starts_with('{') {
            let j = Json::parse(t).map_err(|e| anyhow!("constraint: bad JSON ({e})"))?;
            return ConstraintSpec::parse_json(&j);
        }
        let (name, args) = match t.split_once(':') {
            Some((n, a)) => (n.trim(), Some(a.trim())),
            None => (t, None),
        };
        match (name, args) {
            ("unc" | "unconstrained" | "", None) => Ok(ConstraintSpec::Unconstrained),
            ("l1", None) => Ok(ConstraintSpec::L1Ball { radius: 0.0 }),
            ("l1", Some(a)) => Ok(ConstraintSpec::L1Ball {
                radius: parse_pos(a, "l1 radius")?,
            }),
            ("l2", None) => Ok(ConstraintSpec::L2Ball { radius: 0.0 }),
            ("l2", Some(a)) => Ok(ConstraintSpec::L2Ball {
                radius: parse_pos(a, "l2 radius")?,
            }),
            ("nonneg" | "nn", None) => Ok(ConstraintSpec::NonNeg),
            ("simplex", None) => Ok(ConstraintSpec::Simplex { total: 1.0 }),
            ("simplex", Some(a)) => Ok(ConstraintSpec::Simplex {
                total: parse_pos(a, "simplex total")?,
            }),
            ("box", Some(a)) => {
                let (lo_s, hi_s) = a.split_once(',').ok_or_else(|| {
                    anyhow!("constraint: box needs two bounds, e.g. box:-1,1 (got {a:?})")
                })?;
                let lo: f64 = lo_s
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("constraint: box lo {lo_s:?} is not a number"))?;
                let hi: f64 = hi_s
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("constraint: box hi {hi_s:?} is not a number"))?;
                ensure!(lo <= hi, "constraint: box lo ({lo}) must be <= hi ({hi})");
                Ok(ConstraintSpec::ScalarBox { lo, hi })
            }
            ("box", None) => bail!(
                "constraint: box needs bounds — box:<lo>,<hi> or \
                 {{\"box\":{{\"lo\":[...],\"hi\":[...]}}}}"
            ),
            ("enet" | "elastic_net", Some(a)) => {
                let (alpha_s, radius) = match a.split_once(',') {
                    Some((al, r)) => (al, parse_pos(r, "elastic-net radius")?),
                    None => (a, 0.0),
                };
                let alpha: f64 = alpha_s
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("constraint: enet alpha {alpha_s:?} is not a number"))?;
                ensure!(
                    (0.0..=1.0).contains(&alpha),
                    "constraint: enet alpha must be in [0, 1], got {alpha}"
                );
                Ok(ConstraintSpec::ElasticNet { alpha, radius })
            }
            ("enet" | "elastic_net", None) => {
                bail!("constraint: enet needs at least alpha — enet:<alpha>[,<radius>]")
            }
            _ => bail!(
                "unknown constraint {t:?} (unc | l1[:r] | l2[:r] | nonneg | \
                 simplex[:total] | box:lo,hi | enet:alpha[,r] | a JSON spec — \
                 see DESIGN.md section 12)"
            ),
        }
    }

    /// Parse the JSON form: a string (delegates to
    /// [`ConstraintSpec::parse_str`]) or a single-key object (see the
    /// module table). Errors carry the offending path.
    pub fn parse_json(j: &Json) -> Result<ConstraintSpec> {
        match j {
            Json::Str(s) => ConstraintSpec::parse_str(s),
            Json::Obj(map) => {
                ensure!(
                    map.len() == 1,
                    "constraint: expected one set key, got {:?}",
                    map.keys().collect::<Vec<_>>()
                );
                let (key, val) = map.iter().next().expect("len checked");
                match key.as_str() {
                    "unc" | "unconstrained" => Ok(ConstraintSpec::Unconstrained),
                    "nonneg" => Ok(ConstraintSpec::NonNeg),
                    "l1" | "l2" => {
                        let radius = match val {
                            Json::Num(_) => num_at(val, "constraint.l*")?,
                            _ => num_at(
                                val.req("radius")
                                    .map_err(|_| anyhow!("constraint.{key}: needs \"radius\""))?,
                                &format!("constraint.{key}.radius"),
                            )?,
                        };
                        ensure!(radius >= 0.0, "constraint.{key}.radius must be >= 0");
                        Ok(if key == "l1" {
                            ConstraintSpec::L1Ball { radius }
                        } else {
                            ConstraintSpec::L2Ball { radius }
                        })
                    }
                    "simplex" => {
                        let total = match val {
                            Json::Num(_) => num_at(val, "constraint.simplex")?,
                            Json::Obj(_) => num_at(
                                val.req("total").map_err(|_| {
                                    anyhow!(
                                        "constraint.simplex: needs \"total\" (or use \
                                         the number form {{\"simplex\": 2}} / the \
                                         string form \"simplex\")"
                                    )
                                })?,
                                "constraint.simplex.total",
                            )?,
                            other => bail!(
                                "constraint.simplex: expected a number or object, got {other}"
                            ),
                        };
                        ensure!(total > 0.0, "constraint.simplex.total must be positive");
                        Ok(ConstraintSpec::Simplex { total })
                    }
                    "box" => {
                        let lo_j = val
                            .req("lo")
                            .map_err(|_| anyhow!("constraint.box: needs \"lo\" and \"hi\""))?;
                        let hi_j = val
                            .req("hi")
                            .map_err(|_| anyhow!("constraint.box: needs \"lo\" and \"hi\""))?;
                        match (lo_j, hi_j) {
                            (Json::Num(_), Json::Num(_)) => {
                                let lo = num_at(lo_j, "constraint.box.lo")?;
                                let hi = num_at(hi_j, "constraint.box.hi")?;
                                ensure!(
                                    lo <= hi,
                                    "constraint.box: lo ({lo}) must be <= hi ({hi})"
                                );
                                Ok(ConstraintSpec::ScalarBox { lo, hi })
                            }
                            (Json::Arr(_), Json::Arr(_)) => {
                                let lo = vec_at(lo_j, "constraint.box.lo")?;
                                let hi = vec_at(hi_j, "constraint.box.hi")?;
                                ensure!(
                                    lo.len() == hi.len(),
                                    "constraint.box: lo has {} entries, hi has {}",
                                    lo.len(),
                                    hi.len()
                                );
                                ensure!(!lo.is_empty(), "constraint.box: bounds are empty");
                                for i in 0..lo.len() {
                                    ensure!(
                                        lo[i] <= hi[i],
                                        "constraint.box: lo[{i}] ({}) > hi[{i}] ({})",
                                        lo[i],
                                        hi[i]
                                    );
                                }
                                Ok(ConstraintSpec::CoordBox { lo, hi })
                            }
                            _ => bail!(
                                "constraint.box: lo and hi must both be numbers (scalar \
                                 box) or both arrays (per-coordinate box)"
                            ),
                        }
                    }
                    "elastic_net" | "enet" => {
                        let alpha = num_at(
                            val.req("alpha")
                                .map_err(|_| anyhow!("constraint.{key}: needs \"alpha\""))?,
                            &format!("constraint.{key}.alpha"),
                        )?;
                        ensure!(
                            (0.0..=1.0).contains(&alpha),
                            "constraint.{key}.alpha must be in [0, 1], got {alpha}"
                        );
                        let radius = match val.get("radius") {
                            Some(r) => {
                                let r = num_at(r, &format!("constraint.{key}.radius"))?;
                                ensure!(r >= 0.0, "constraint.{key}.radius must be >= 0");
                                r
                            }
                            None => 0.0,
                        };
                        Ok(ConstraintSpec::ElasticNet { alpha, radius })
                    }
                    "affine_eq" | "affine" => {
                        let c_j = val
                            .req("c")
                            .map_err(|_| anyhow!("constraint.{key}: needs \"c\" and \"e\""))?;
                        let e_j = val
                            .req("e")
                            .map_err(|_| anyhow!("constraint.{key}: needs \"c\" and \"e\""))?;
                        let rows = c_j.as_arr().ok_or_else(|| {
                            anyhow!("constraint.{key}.c: expected an array of rows")
                        })?;
                        ensure!(!rows.is_empty(), "constraint.{key}.c: no rows");
                        let c: Vec<Vec<f64>> = rows
                            .iter()
                            .enumerate()
                            .map(|(i, r)| vec_at(r, &format!("constraint.{key}.c[{i}]")))
                            .collect::<Result<_>>()?;
                        let d = c[0].len();
                        ensure!(d > 0, "constraint.{key}.c: rows are empty");
                        for (i, row) in c.iter().enumerate() {
                            ensure!(
                                row.len() == d,
                                "constraint.{key}.c[{i}]: has {} entries, expected {d}",
                                row.len()
                            );
                        }
                        let e = vec_at(e_j, &format!("constraint.{key}.e"))?;
                        ensure!(
                            e.len() == c.len(),
                            "constraint.{key}: e has {} entries for {} rows of c",
                            e.len(),
                            c.len()
                        );
                        Ok(ConstraintSpec::AffineEq { c, e })
                    }
                    other => bail!(
                        "unknown constraint key {other:?} (l1 | l2 | box | simplex | \
                         elastic_net | affine_eq | nonneg | unc)"
                    ),
                }
            }
            other => bail!("constraint: expected a string or object, got {other}"),
        }
    }

    /// Serialize back to the wire form ([`ConstraintSpec::parse_json`]
    /// round-trips it).
    pub fn to_json(&self) -> Json {
        match self {
            ConstraintSpec::Unconstrained => Json::str("unc"),
            ConstraintSpec::NonNeg => Json::str("nonneg"),
            ConstraintSpec::L1Ball { radius } if *radius == 0.0 => Json::str("l1"),
            ConstraintSpec::L1Ball { radius } => {
                Json::obj(vec![("l1", Json::num(*radius))])
            }
            ConstraintSpec::L2Ball { radius } if *radius == 0.0 => Json::str("l2"),
            ConstraintSpec::L2Ball { radius } => {
                Json::obj(vec![("l2", Json::num(*radius))])
            }
            ConstraintSpec::Simplex { total } if *total == 1.0 => Json::str("simplex"),
            ConstraintSpec::Simplex { total } => {
                Json::obj(vec![("simplex", Json::num(*total))])
            }
            ConstraintSpec::ScalarBox { lo, hi } => Json::obj(vec![(
                "box",
                Json::obj(vec![("lo", Json::num(*lo)), ("hi", Json::num(*hi))]),
            )]),
            ConstraintSpec::CoordBox { lo, hi } => {
                let arr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::num(x)).collect());
                Json::obj(vec![(
                    "box",
                    Json::obj(vec![("lo", arr(lo)), ("hi", arr(hi))]),
                )])
            }
            ConstraintSpec::ElasticNet { alpha, radius } => Json::obj(vec![(
                "elastic_net",
                Json::obj(vec![
                    ("alpha", Json::num(*alpha)),
                    ("radius", Json::num(*radius)),
                ]),
            )]),
            ConstraintSpec::AffineEq { c, e } => {
                let arr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::num(x)).collect());
                Json::obj(vec![(
                    "affine_eq",
                    Json::obj(vec![
                        ("c", Json::Arr(c.iter().map(|r| arr(r)).collect())),
                        ("e", arr(e)),
                    ]),
                )])
            }
        }
    }

    /// Whether this is W = R^d (the scheduler's PJRT-eligibility guard).
    pub fn is_unconstrained(&self) -> bool {
        matches!(self, ConstraintSpec::Unconstrained)
    }

    /// The radius embedded in the spec itself (0 when absent or not a
    /// radius-bearing set). A positive value here wins over the request's
    /// legacy top-level `radius` field.
    pub fn radius_param(&self) -> f64 {
        match self {
            ConstraintSpec::L1Ball { radius }
            | ConstraintSpec::L2Ball { radius }
            | ConstraintSpec::ElasticNet { radius, .. } => *radius,
            _ => 0.0,
        }
    }

    /// The paper-protocol derived radius given the unconstrained optimum's
    /// norms: l1/l2 balls use ||x*||_1 / ||x*||_2, the elastic-net ball the
    /// penalty *value* at x* — in every case x* sits on the boundary, so
    /// the constrained and unconstrained optima coincide. 0 for sets with
    /// no radius.
    pub fn derived_radius(&self, l1_star: f64, l2_star: f64) -> f64 {
        match self {
            ConstraintSpec::L1Ball { .. } => l1_star,
            ConstraintSpec::L2Ball { .. } => l2_star,
            ConstraintSpec::ElasticNet { alpha, .. } => {
                alpha * l1_star + 0.5 * (1.0 - alpha) * l2_star * l2_star
            }
            _ => 0.0,
        }
    }

    /// The tag the built set will report (for validation errors and logs
    /// before a set exists).
    pub fn tag(&self) -> &'static str {
        match self {
            ConstraintSpec::Unconstrained => "unc",
            ConstraintSpec::L1Ball { .. } => "l1",
            ConstraintSpec::L2Ball { .. } => "l2",
            ConstraintSpec::NonNeg => "nonneg",
            ConstraintSpec::Simplex { .. } => "simplex",
            ConstraintSpec::ScalarBox { .. } | ConstraintSpec::CoordBox { .. } => "box",
            ConstraintSpec::ElasticNet { .. } => "enet",
            ConstraintSpec::AffineEq { .. } => "affine",
        }
    }

    /// Build the runtime set. `resolved_radius` is the coordinator-resolved
    /// scalar for radius-bearing sets (spec radius if positive, else the
    /// request's `radius` field, else the derived paper default); sets
    /// without a radius ignore it. Fails when a ball set still has no
    /// positive radius, or when a set's own invariants do not hold
    /// (dependent affine rows, lo > hi, ...).
    pub fn build(&self, resolved_radius: f64) -> Result<ConstraintRef> {
        let ball_radius = |name: &str| -> Result<f64> {
            let r = if self.radius_param() > 0.0 {
                self.radius_param()
            } else {
                resolved_radius
            };
            ensure!(
                r > 0.0,
                "constraint {name}: radius must be positive (0 means derive from the \
                 unconstrained optimum, which only the coordinator can resolve)"
            );
            Ok(r)
        };
        match self {
            ConstraintSpec::Unconstrained => Ok(super::unconstrained()),
            ConstraintSpec::L1Ball { .. } => Ok(super::l1_ball(ball_radius("l1")?)),
            ConstraintSpec::L2Ball { .. } => Ok(super::l2_ball(ball_radius("l2")?)),
            ConstraintSpec::NonNeg => Ok(super::nonneg()),
            ConstraintSpec::Simplex { total } => {
                ensure!(*total > 0.0, "constraint simplex: total must be positive");
                Ok(super::simplex(*total))
            }
            ConstraintSpec::ScalarBox { lo, hi } => {
                ensure!(lo <= hi, "constraint box: lo ({lo}) must be <= hi ({hi})");
                Ok(super::scalar_box(*lo, *hi))
            }
            ConstraintSpec::CoordBox { lo, hi } => {
                ensure!(
                    lo.len() == hi.len() && !lo.is_empty(),
                    "constraint box: malformed bounds"
                );
                Ok(super::coord_box(lo.clone(), hi.clone()))
            }
            ConstraintSpec::ElasticNet { alpha, .. } => {
                ensure!(
                    (0.0..=1.0).contains(alpha),
                    "constraint enet: alpha must be in [0, 1]"
                );
                Ok(super::elastic_net(*alpha, ball_radius("enet")?))
            }
            ConstraintSpec::AffineEq { c, e } => {
                let k = c.len();
                let d = c.first().map(|r| r.len()).unwrap_or(0);
                let mut m = Mat::zeros(k, d);
                for (i, row) in c.iter().enumerate() {
                    ensure!(
                        row.len() == d,
                        "constraint affine_eq: ragged rows ({} vs {d})",
                        row.len()
                    );
                    m.row_mut(i).copy_from_slice(row);
                }
                super::affine_eq(m, e.clone())
            }
        }
    }
}

impl std::str::FromStr for ConstraintSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ConstraintSpec> {
        ConstraintSpec::parse_str(s)
    }
}

/// Infallible conversion for in-repo literals (tests, experiments,
/// examples): panics with the parse error on an invalid spec. User input
/// must go through [`ConstraintSpec::parse_str`] / [`ConstraintSpec::parse_json`].
impl From<&str> for ConstraintSpec {
    fn from(s: &str) -> ConstraintSpec {
        ConstraintSpec::parse_str(s).expect("constraint spec literal")
    }
}

impl std::fmt::Display for ConstraintSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::ConstraintSet;

    #[test]
    fn string_forms_parse() {
        assert_eq!(
            ConstraintSpec::parse_str("unc").unwrap(),
            ConstraintSpec::Unconstrained
        );
        assert_eq!(
            ConstraintSpec::parse_str("l1").unwrap(),
            ConstraintSpec::L1Ball { radius: 0.0 }
        );
        assert_eq!(
            ConstraintSpec::parse_str("l1:0.5").unwrap(),
            ConstraintSpec::L1Ball { radius: 0.5 }
        );
        assert_eq!(
            ConstraintSpec::parse_str("simplex").unwrap(),
            ConstraintSpec::Simplex { total: 1.0 }
        );
        assert_eq!(
            ConstraintSpec::parse_str("simplex:2").unwrap(),
            ConstraintSpec::Simplex { total: 2.0 }
        );
        assert_eq!(
            ConstraintSpec::parse_str("nonneg").unwrap(),
            ConstraintSpec::NonNeg
        );
        assert_eq!(
            ConstraintSpec::parse_str("box:-1,1").unwrap(),
            ConstraintSpec::ScalarBox { lo: -1.0, hi: 1.0 }
        );
        assert_eq!(
            ConstraintSpec::parse_str("enet:0.5,1.5").unwrap(),
            ConstraintSpec::ElasticNet {
                alpha: 0.5,
                radius: 1.5
            }
        );
        assert_eq!(
            ConstraintSpec::parse_str("enet:0.25").unwrap(),
            ConstraintSpec::ElasticNet {
                alpha: 0.25,
                radius: 0.0
            }
        );
    }

    #[test]
    fn bad_strings_error_with_guidance() {
        for bad in ["l7", "box", "box:1", "box:2,1", "enet", "enet:1.5", "simplex:-1"] {
            let err = ConstraintSpec::parse_str(bad).unwrap_err();
            assert!(!format!("{err}").is_empty(), "{bad}");
        }
    }

    #[test]
    fn json_forms_parse_and_roundtrip() {
        let cases = [
            r#""unc""#,
            r#""nonneg""#,
            r#""simplex""#,
            r#"{"l1": 0.5}"#,
            r#"{"l2": {"radius": 2}}"#,
            r#"{"simplex": 3}"#,
            r#"{"box": {"lo": -1, "hi": 1}}"#,
            r#"{"box": {"lo": [0, -1], "hi": [1, 1]}}"#,
            r#"{"elastic_net": {"alpha": 0.5, "radius": 1.5}}"#,
            r#"{"affine_eq": {"c": [[1, 1, 1]], "e": [1]}}"#,
        ];
        for case in cases {
            let j = Json::parse(case).unwrap();
            let spec = ConstraintSpec::parse_json(&j).unwrap();
            let back = ConstraintSpec::parse_json(&spec.to_json()).unwrap();
            assert_eq!(spec, back, "{case}");
        }
    }

    #[test]
    fn json_errors_carry_paths() {
        let bad = [
            (r#"{"box": {"lo": [0, 1], "hi": [1]}}"#, "lo has 2"),
            (r#"{"box": {"lo": "x", "hi": 1}}"#, "constraint.box"),
            (r#"{"box": {"lo": [2], "hi": [1]}}"#, "lo[0]"),
            (r#"{"affine_eq": {"c": [[1, 2], [3]], "e": [1, 2]}}"#, "c[1]"),
            (r#"{"affine_eq": {"c": [[1, 2]], "e": [1, 2]}}"#, "e has 2"),
            (r#"{"elastic_net": {"alpha": 2}}"#, "alpha"),
            (r#"{"simplex": {}}"#, "total"),
            (r#"{"simplex": {"totl": 2}}"#, "total"),
            (r#"{"warp": 9}"#, "unknown constraint key"),
        ];
        for (case, needle) in bad {
            let j = Json::parse(case).unwrap();
            let err = format!("{:#}", ConstraintSpec::parse_json(&j).unwrap_err());
            assert!(err.contains(needle), "{case}: {err}");
        }
    }

    #[test]
    fn radius_resolution_order() {
        // spec radius wins over the resolved fallback
        let spec = ConstraintSpec::L1Ball { radius: 2.0 };
        let built = spec.build(5.0).unwrap();
        assert_eq!(built.radius(), 2.0);
        // radius 0 takes the fallback
        let spec0 = ConstraintSpec::L1Ball { radius: 0.0 };
        assert_eq!(spec0.build(5.0).unwrap().radius(), 5.0);
        // no radius at all is an error for balls...
        assert!(spec0.build(0.0).is_err());
        // ...but fine for radius-free sets
        assert!(ConstraintSpec::NonNeg.build(0.0).is_ok());
        // derived radius: enet uses the penalty value at x*
        let enet = ConstraintSpec::ElasticNet {
            alpha: 0.5,
            radius: 0.0,
        };
        let derived = enet.derived_radius(3.0, 2.0);
        assert!((derived - (0.5 * 3.0 + 0.25 * 4.0)).abs() < 1e-12);
    }

    #[test]
    fn build_produces_matching_tags() {
        let cases: Vec<(ConstraintSpec, &str)> = vec![
            (ConstraintSpec::Unconstrained, "unc"),
            (ConstraintSpec::L1Ball { radius: 1.0 }, "l1"),
            (ConstraintSpec::L2Ball { radius: 1.0 }, "l2"),
            (ConstraintSpec::NonNeg, "nonneg"),
            (ConstraintSpec::Simplex { total: 1.0 }, "simplex"),
            (ConstraintSpec::ScalarBox { lo: -1.0, hi: 1.0 }, "box"),
            (
                ConstraintSpec::CoordBox {
                    lo: vec![0.0],
                    hi: vec![1.0],
                },
                "box",
            ),
            (
                ConstraintSpec::ElasticNet {
                    alpha: 0.5,
                    radius: 1.0,
                },
                "enet",
            ),
            (
                ConstraintSpec::AffineEq {
                    c: vec![vec![1.0, 1.0]],
                    e: vec![1.0],
                },
                "affine",
            ),
        ];
        for (spec, tag) in cases {
            assert_eq!(spec.tag(), tag);
            assert_eq!(spec.build(1.0).unwrap().tag(), tag);
        }
    }

    #[test]
    fn from_str_literals_work() {
        let spec: ConstraintSpec = "l2".into();
        assert_eq!(spec, ConstraintSpec::L2Ball { radius: 0.0 });
        let parsed: ConstraintSpec = "simplex".parse().unwrap();
        assert_eq!(parsed, ConstraintSpec::Simplex { total: 1.0 });
    }
}
