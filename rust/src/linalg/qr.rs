//! Householder thin-QR.
//!
//! Algorithm 1 of the paper QR-factors the sketched matrix `SA` (s x d with
//! s = O(d log d) << n), so this runs on *small* inputs — clarity and
//! numerical robustness matter more than blocking. We still keep the
//! reflector application cache-friendly (row-major, applied panel-wise).

use super::blas;
use super::matrix::Mat;

/// Result of a thin QR: `r` is d x d upper-triangular with non-negative
/// diagonal; `q` (optional) is m x d with orthonormal columns.
pub struct QrResult {
    /// Thin Q (m x d, orthonormal columns) when requested, else `None`.
    pub q: Option<Mat>,
    /// Upper-triangular R (d x d) with non-negative diagonal.
    pub r: Mat,
}

/// Householder QR of a (m x d, m >= d). Returns R only (the paper's
/// Algorithm 1 step 2 needs just R to form the preconditioner).
pub fn qr_r(a: &Mat) -> Mat {
    qr_impl(a, false).r
}

/// Householder QR returning both Q (thin) and R.
pub fn qr(a: &Mat) -> QrResult {
    qr_impl(a, true)
}

fn qr_impl(a: &Mat, want_q: bool) -> QrResult {
    let (m, d) = (a.rows, a.cols);
    assert!(m >= d, "thin QR needs m >= d (got {m} x {d})");
    let mut work = a.clone();
    // store reflectors v_k in the lower part of work + betas
    let mut betas = vec![0.0; d];
    for k in 0..d {
        // build the Householder vector for column k from rows k..m
        let mut norm2 = 0.0;
        for i in k..m {
            let v = work.at(i, k);
            norm2 += v * v;
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            betas[k] = 0.0;
            continue;
        }
        let akk = work.at(k, k);
        let alpha = if akk >= 0.0 { -norm } else { norm };
        // v = x - alpha e1 ; normalized so v[k] = 1
        let v0 = akk - alpha;
        betas[k] = -v0 / alpha; // = 2 / (v^T v) * v0^2 scaled form
        let inv_v0 = 1.0 / v0;
        for i in (k + 1)..m {
            *work.at_mut(i, k) *= inv_v0;
        }
        *work.at_mut(k, k) = alpha;
        // apply (I - beta v v^T) to the trailing columns
        let beta = betas[k];
        for j in (k + 1)..d {
            // w = v^T col_j  (v[k] = 1 implicit)
            let mut w = work.at(k, j);
            for i in (k + 1)..m {
                w += work.at(i, k) * work.at(i, j);
            }
            w *= beta;
            *work.at_mut(k, j) -= w;
            for i in (k + 1)..m {
                let vik = work.at(i, k);
                *work.at_mut(i, j) -= w * vik;
            }
        }
    }
    // extract R with non-negative diagonal (flip row signs as needed)
    let mut r = Mat::zeros(d, d);
    let mut flips = vec![false; d];
    for i in 0..d {
        let diag = work.at(i, i);
        flips[i] = diag < 0.0;
        let s = if flips[i] { -1.0 } else { 1.0 };
        for j in i..d {
            *r.at_mut(i, j) = s * work.at(i, j);
        }
    }
    let q = if want_q {
        // accumulate Q = H_0 ... H_{d-1} I_thin
        let mut q = Mat::zeros(m, d);
        for i in 0..d {
            *q.at_mut(i, i) = 1.0;
        }
        for k in (0..d).rev() {
            let beta = betas[k];
            if beta == 0.0 {
                continue;
            }
            for j in 0..d {
                let mut w = q.at(k, j);
                for i in (k + 1)..m {
                    w += work.at(i, k) * q.at(i, j);
                }
                w *= beta;
                *q.at_mut(k, j) -= w;
                for i in (k + 1)..m {
                    let vik = work.at(i, k);
                    *q.at_mut(i, j) -= w * vik;
                }
            }
        }
        // apply the same sign flips to Q's columns
        for (k, &flip) in flips.iter().enumerate() {
            if flip {
                for i in 0..m {
                    *q.at_mut(i, k) = -q.at(i, k);
                }
            }
        }
        Some(q)
    } else {
        None
    };
    QrResult { q, r }
}

/// Solve the unconstrained least-squares problem min ||Ax - b|| via QR of A.
/// Used as the exact ground-truth solver (f(x*)) for the figures.
pub fn lstsq(a: &Mat, b: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, b.len());
    let QrResult { q, r } = qr(a);
    let q = q.expect("qr with q");
    // x = R^{-1} Q^T b
    let qtb = blas::gemv_t(&q, b);
    super::tri::solve_upper(&r, &qtb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn r_is_upper_triangular_with_nonneg_diag() {
        let mut rng = Rng::new(1);
        let a = Mat::gaussian(50, 8, &mut rng);
        let r = qr_r(&a);
        for i in 0..8 {
            assert!(r.at(i, i) >= 0.0);
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_reconstructs_a() {
        let mut rng = Rng::new(2);
        let a = Mat::gaussian(40, 7, &mut rng);
        let QrResult { q, r } = qr(&a);
        let q = q.unwrap();
        let qr_prod = blas::gemm(&q, &r);
        assert!(qr_prod.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::new(3);
        let a = Mat::gaussian(60, 10, &mut rng);
        let q = qr(&a).q.unwrap();
        let qtq = blas::gram(&q);
        assert!(qtq.max_abs_diff(&Mat::eye(10)) < 1e-10);
    }

    #[test]
    fn gram_of_a_equals_rtr() {
        // The preconditioner identity the paper relies on: A^T A = R^T R.
        let mut rng = Rng::new(4);
        let a = Mat::gaussian(80, 6, &mut rng);
        let r = qr_r(&a);
        let rtr = blas::gemm(&r.transpose(), &r);
        let ata = blas::gram(&a);
        assert!(rtr.max_abs_diff(&ata) < 1e-8);
    }

    #[test]
    fn lstsq_recovers_planted_solution() {
        let mut rng = Rng::new(5);
        let a = Mat::gaussian(100, 5, &mut rng);
        let xstar = rng.gaussians(5);
        let b = blas::gemv(&a, &xstar);
        let x = lstsq(&a, &b);
        for (u, v) in x.iter().zip(&xstar) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn lstsq_residual_is_orthogonal_to_range() {
        let mut rng = Rng::new(6);
        let a = Mat::gaussian(90, 4, &mut rng);
        let b = rng.gaussians(90);
        let x = lstsq(&a, &b);
        let r = blas::sub(&blas::gemv(&a, &x), &b);
        let atr = blas::gemv_t(&a, &r);
        for v in atr {
            assert!(v.abs() < 1e-9);
        }
    }

    #[test]
    fn handles_square_and_nearly_rank_deficient() {
        let mut rng = Rng::new(7);
        let a = Mat::gaussian(6, 6, &mut rng);
        let QrResult { q, r } = qr(&a);
        let prod = blas::gemm(&q.unwrap(), &r);
        assert!(prod.max_abs_diff(&a) < 1e-10);
    }
}
