//! Linear algebra substrate, written from scratch.
//!
//! The solvers need exactly: a row-major dense matrix type, a CSR sparse
//! matrix type for the input-sparsity-time pipeline, fast matrix-matrix /
//! matrix-vector products (the native-backend hot path), Householder
//! thin-QR (Algorithm 1's factorization of the sketch `SA`), triangular
//! solves (applying `R^{-1}`), and symmetric eigensolves on small Gram
//! matrices (condition numbers for Table 2 / dataset construction).

pub mod matrix;
pub mod sparse;
pub mod blas;
pub mod qr;
pub mod tri;
pub mod eigen;

pub use matrix::Mat;
pub use sparse::CsrMat;
