//! Symmetric eigensolver (cyclic Jacobi) + condition-number utilities.
//!
//! Used for (a) measuring kappa(AR^{-1}) in Table 2, (b) constructing
//! synthetic datasets with an exact target condition number, and
//! (c) estimating smoothness/strong-convexity constants for step sizes.
//! Matrices here are d x d Gram matrices (d <= ~100), where Jacobi is both
//! simple and accurate.

use super::blas;
use super::matrix::Mat;

/// Full symmetric eigendecomposition A = V diag(vals) V^T via cyclic
/// Jacobi, accumulating the rotations. `vals` ascending; columns of `v`
/// are the matching eigenvectors.
pub struct SymEigen {
    /// Eigenvalues, ascending.
    pub vals: Vec<f64>,
    /// Eigenvectors as columns, ordered to match `vals`.
    pub v: Mat,
}

/// Full symmetric eigendecomposition of a d x d matrix (see [`SymEigen`]).
pub fn sym_eigen(a: &Mat) -> SymEigen {
    let d = a.rows;
    assert_eq!(a.cols, d);
    let mut m = a.clone();
    let mut v = Mat::eye(d);
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..d {
            for j in (i + 1)..d {
                off += m.at(i, j) * m.at(i, j);
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m.at(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                for k in 0..d {
                    let akp = m.at(k, p);
                    let akq = m.at(k, q);
                    *m.at_mut(k, p) = c * akp - s * akq;
                    *m.at_mut(k, q) = s * akp + c * akq;
                }
                for k in 0..d {
                    let apk = m.at(p, k);
                    let aqk = m.at(q, k);
                    *m.at_mut(p, k) = c * apk - s * aqk;
                    *m.at_mut(q, k) = s * apk + c * aqk;
                }
                // accumulate V <- V J
                for k in 0..d {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    *v.at_mut(k, p) = c * vkp - s * vkq;
                    *v.at_mut(k, q) = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort ascending, permuting V's columns
    let mut order: Vec<usize> = (0..d).collect();
    let diag: Vec<f64> = (0..d).map(|i| m.at(i, i)).collect();
    order.sort_by(|&i, &j| diag[i].partial_cmp(&diag[j]).unwrap());
    let vals: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vs = Mat::zeros(d, d);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..d {
            *vs.at_mut(i, new_j) = v.at(i, old_j);
        }
    }
    SymEigen { vals, v: vs }
}

/// Eigenvalues (ascending) of a symmetric matrix via cyclic Jacobi.
pub fn sym_eigenvalues(a: &Mat) -> Vec<f64> {
    let d = a.rows;
    assert_eq!(a.cols, d);
    let mut m = a.clone();
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..d {
            for j in (i + 1)..d {
                off += m.at(i, j) * m.at(i, j);
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m.at(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p and q
                for k in 0..d {
                    let akp = m.at(k, p);
                    let akq = m.at(k, q);
                    *m.at_mut(k, p) = c * akp - s * akq;
                    *m.at_mut(k, q) = s * akp + c * akq;
                }
                for k in 0..d {
                    let apk = m.at(p, k);
                    let aqk = m.at(q, k);
                    *m.at_mut(p, k) = c * apk - s * aqk;
                    *m.at_mut(q, k) = s * apk + c * aqk;
                }
            }
        }
    }
    let mut evs: Vec<f64> = (0..d).map(|i| m.at(i, i)).collect();
    evs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    evs
}

/// Singular values of a tall matrix via eigenvalues of its Gram matrix.
pub fn singular_values(a: &Mat) -> Vec<f64> {
    let g = blas::gram(a);
    sym_eigenvalues(&g)
        .into_iter()
        .map(|l| l.max(0.0).sqrt())
        .collect()
}

/// Condition number sigma_max / sigma_min of a tall full-rank matrix.
pub fn cond(a: &Mat) -> f64 {
    let sv = singular_values(a);
    let smin = sv.first().copied().unwrap_or(0.0);
    let smax = sv.last().copied().unwrap_or(0.0);
    if smin <= 0.0 {
        f64::INFINITY
    } else {
        smax / smin
    }
}

/// Condition number of AR^{-1} *without* forming the n x d product:
/// kappa(AR^{-1})^2 = kappa(R^{-T} (A^T A) R^{-1}); we form the small d x d
/// matrix via triangular solves against the Gram matrix columns.
pub fn cond_preconditioned(gram_a: &Mat, r: &Mat) -> f64 {
    let d = gram_a.rows;
    // B = R^{-T} G R^{-1}: solve column-wise
    let mut b = Mat::zeros(d, d);
    for j in 0..d {
        // col_j of G R^{-1}: solve R^T y = G e_j? careful:
        // G R^{-1} has columns G (R^{-1} e_j); R^{-1} e_j = solve_upper(R, e_j)
        let mut e = vec![0.0; d];
        e[j] = 1.0;
        let rinv_ej = super::tri::solve_upper(r, &e);
        let g_col = blas::gemv(gram_a, &rinv_ej);
        let col = super::tri::solve_upper_t(r, &g_col);
        for i in 0..d {
            *b.at_mut(i, j) = col[i];
        }
    }
    // symmetrize numerical noise
    for i in 0..d {
        for j in (i + 1)..d {
            let avg = 0.5 * (b.at(i, j) + b.at(j, i));
            *b.at_mut(i, j) = avg;
            *b.at_mut(j, i) = avg;
        }
    }
    let evs = sym_eigenvalues(&b);
    let lmin = evs.first().copied().unwrap_or(0.0);
    let lmax = evs.last().copied().unwrap_or(0.0);
    if lmin <= 0.0 {
        f64::INFINITY
    } else {
        (lmax / lmin).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::qr_r;
    use crate::util::rng::Rng;

    #[test]
    fn eigenvalues_of_diagonal() {
        let mut m = Mat::zeros(4, 4);
        for (i, v) in [4.0, 1.0, 3.0, 2.0].iter().enumerate() {
            *m.at_mut(i, i) = *v;
        }
        let evs = sym_eigenvalues(&m);
        assert_eq!(evs, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn eigenvalues_match_trace_and_det_2x2() {
        let m = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let evs = sym_eigenvalues(&m);
        assert!((evs[0] - 1.0).abs() < 1e-12);
        assert!((evs[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn gram_eigs_are_nonnegative() {
        let mut rng = Rng::new(1);
        let a = Mat::gaussian(50, 8, &mut rng);
        let evs = sym_eigenvalues(&blas::gram(&a));
        assert!(evs.iter().all(|&l| l > -1e-9));
    }

    #[test]
    fn singular_values_of_orthogonal_are_one() {
        let mut rng = Rng::new(2);
        let a = Mat::gaussian(40, 6, &mut rng);
        let q = crate::linalg::qr::qr(&a).q.unwrap();
        let sv = singular_values(&q);
        for s in sv {
            assert!((s - 1.0).abs() < 1e-8);
        }
    }

    #[test]
    fn cond_of_scaled_identityish() {
        // diag(1..5) embedded in a tall matrix via known construction
        let mut a = Mat::zeros(10, 5);
        for i in 0..5 {
            *a.at_mut(i, i) = (i + 1) as f64;
        }
        assert!((cond(&a) - 5.0).abs() < 1e-8);
    }

    #[test]
    fn preconditioning_kills_condition_number() {
        // The core claim behind Table 2: kappa(A R^{-1}) = O(1) when R is the
        // R-factor of (a sketch of) A. With the exact QR, kappa == 1.
        let mut rng = Rng::new(3);
        let a = Mat::gaussian(200, 10, &mut rng);
        let r = qr_r(&a);
        let g = blas::gram(&a);
        let k = cond_preconditioned(&g, &r);
        assert!(
            (k - 1.0).abs() < 1e-6,
            "exact preconditioning should give kappa=1, got {k}"
        );
    }

    #[test]
    fn cond_preconditioned_matches_explicit_product() {
        let mut rng = Rng::new(4);
        let a = Mat::gaussian(120, 6, &mut rng);
        // an *approximate* R (from a sub-sampled QR) leaves kappa > 1
        let sub = a.gather_rows(&(0..40).collect::<Vec<_>>());
        let r = qr_r(&sub);
        let g = blas::gram(&a);
        let fast = cond_preconditioned(&g, &r);
        // explicit U = A R^{-1}
        let rinv = crate::linalg::tri::inv_upper(&r);
        let u = blas::gemm(&a, &rinv);
        let explicit = cond(&u);
        assert!(
            (fast - explicit).abs() < 1e-6 * explicit,
            "fast {fast} vs explicit {explicit}"
        );
    }
}
