//! Row-major dense matrix over f64.
//!
//! Row-major is the natural layout here: every solver samples *rows* of the
//! (preconditioned) data matrix, so a mini-batch gather is `r` contiguous
//! memcpys, and the PJRT literal layout (default XLA major-to-minor) matches
//! byte-for-byte.

use crate::util::alloc::AlignedBuf;
use crate::util::rng::Rng;

/// Row-major dense matrix over `f64`, backed by a 64-byte-aligned buffer
/// ([`AlignedBuf`]) so SIMD kernels hit aligned cache-line loads.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns (the contiguous, fast axis).
    pub cols: usize,
    /// Row-major backing storage, `rows * cols` elements, 64-byte aligned.
    pub data: AlignedBuf,
}

impl Default for Mat {
    /// The empty 0 x 0 matrix — placeholder for lazily initialized state.
    fn default() -> Mat {
        Mat::zeros(0, 0)
    }
}

impl Mat {
    /// The `rows x cols` zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: AlignedBuf::zeroed(rows * cols),
        }
    }

    /// Wrap a row-major data vector (copied into aligned storage).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat {
            rows,
            cols,
            data: AlignedBuf::from_vec(data),
        }
    }

    /// Build from a closure f(i, j), called in row-major order.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut out = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                out.data[i * cols + j] = f(i, j);
            }
        }
        out
    }

    /// The `n x n` identity.
    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// iid standard-normal entries drawn from `rng` in row-major order.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Mat {
            rows,
            cols,
            data: AlignedBuf::from_vec(rng.gaussians(rows * cols)),
        }
    }

    /// Element `(i, j)`.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Mutable element `(i, j)`.
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    /// Row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable contiguous slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Column `j`, copied out (columns are strided).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// The transposed matrix (copies).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // simple cache-blocked transpose
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Gather rows by index into a new (idx.len() x cols) matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Horizontal stack [self | other].
    pub fn hstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Pack [self | col] into a fresh matrix with `rows_out >= rows` rows
    /// (extra rows zero). One allocation, one pass — the streaming
    /// precondition pipeline uses this to build the padded [A | b] FWHT
    /// buffer directly instead of hstack-then-pad (which materializes the
    /// dense [A | b] twice).
    pub fn hstack_col_padded(&self, col: &[f64], rows_out: usize) -> Mat {
        assert_eq!(self.rows, col.len());
        assert!(rows_out >= self.rows);
        let d = self.cols;
        let mut out = Mat::zeros(rows_out, d + 1);
        for i in 0..self.rows {
            let orow = out.row_mut(i);
            orow[..d].copy_from_slice(self.row(i));
            orow[d] = col[i];
        }
        out
    }

    /// Split off the last column *in place* (no second allocation for the
    /// left block): rows are compacted forward within the existing buffer.
    /// Counterpart of [`Mat::split_last_col`] for owned packed matrices.
    pub fn into_split_last_col(mut self) -> (Mat, Vec<f64>) {
        assert!(self.cols >= 1);
        let d = self.cols - 1;
        let n = self.rows;
        let mut b = Vec::with_capacity(n);
        for i in 0..n {
            let src = i * (d + 1);
            // read b[i] before compacting: later rows' writes stay below
            // their own source offsets, so forward compaction never clobbers
            // unread data
            b.push(self.data[src + d]);
            self.data.copy_within(src..src + d, i * d);
        }
        self.data.truncate(n * d);
        self.cols = d;
        (self, b)
    }

    /// Split off the last column (used for the packed [A | b] layout).
    pub fn split_last_col(&self) -> (Mat, Vec<f64>) {
        assert!(self.cols >= 1);
        let d = self.cols - 1;
        let mut a = Mat::zeros(self.rows, d);
        let mut b = Vec::with_capacity(self.rows);
        for i in 0..self.rows {
            a.row_mut(i).copy_from_slice(&self.row(i)[..d]);
            b.push(self.row(i)[d]);
        }
        (a, b)
    }

    /// Take the first `rows` rows.
    pub fn top_rows(&self, rows: usize) -> Mat {
        assert!(rows <= self.rows);
        Mat {
            rows,
            cols: self.cols,
            data: crate::util::alloc::AlignedBuf::from_slice(&self.data[..rows * self.cols]),
        }
    }

    /// Pad with zero rows up to `rows` (power-of-two padding for FWHT).
    pub fn pad_rows(&self, rows: usize) -> Mat {
        assert!(rows >= self.rows);
        let mut data = self.data.clone();
        data.resize(rows * self.cols, 0.0);
        Mat {
            rows,
            cols: self.cols,
            data,
        }
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Largest entrywise absolute difference against `other` (same shape).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Multiply every entry by `s` in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }
}

/// next power of two >= n (FWHT padding).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Mat::gaussian(37, 53, &mut rng);
        let t = m.transpose();
        assert_eq!((t.rows, t.cols), (53, 37));
        assert_eq!(t.transpose(), m);
        for i in 0..m.rows {
            for j in 0..m.cols {
                assert_eq!(m.at(i, j), t.at(j, i));
            }
        }
    }

    #[test]
    fn gather_rows_copies() {
        let m = Mat::from_fn(5, 2, |i, j| (i * 2 + j) as f64);
        let g = m.gather_rows(&[4, 0, 4]);
        assert_eq!(g.rows, 3);
        assert_eq!(g.row(0), &[8., 9.]);
        assert_eq!(g.row(1), &[0., 1.]);
        assert_eq!(g.row(2), &[8., 9.]);
    }

    #[test]
    fn hstack_and_split() {
        let a = Mat::from_fn(3, 2, |i, j| (i + j) as f64);
        let b = Mat::from_fn(3, 1, |i, _| 100.0 + i as f64);
        let ab = a.hstack(&b);
        assert_eq!(ab.cols, 3);
        let (a2, bv) = ab.split_last_col();
        assert_eq!(a2, a);
        assert_eq!(bv, vec![100., 101., 102.]);
    }

    #[test]
    fn packed_padded_matches_hstack_then_pad() {
        let mut rng = Rng::new(7);
        for (n, pad) in [(5usize, 8usize), (8, 8), (1, 4)] {
            let a = Mat::gaussian(n, 3, &mut rng);
            let b = rng.gaussians(n);
            let direct = a.hstack_col_padded(&b, pad);
            let bmat = Mat::from_vec(n, 1, b.clone());
            let two_step = a.hstack(&bmat).pad_rows(pad);
            assert_eq!(direct, two_step, "n={n} pad={pad}");
        }
    }

    #[test]
    fn into_split_matches_copy_split() {
        let mut rng = Rng::new(8);
        for (n, d) in [(6usize, 4usize), (1, 1), (9, 2)] {
            let m = Mat::gaussian(n, d + 1, &mut rng);
            let (want_a, want_b) = m.split_last_col();
            let (got_a, got_b) = m.clone().into_split_last_col();
            assert_eq!(got_a, want_a, "n={n} d={d}");
            assert_eq!(got_b, want_b);
        }
    }

    #[test]
    fn pad_and_top() {
        let m = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        let p = m.pad_rows(8);
        assert_eq!(p.rows, 8);
        assert_eq!(p.row(7), &[0., 0.]);
        assert_eq!(p.top_rows(3), m);
    }

    #[test]
    fn eye_and_frob() {
        let i3 = Mat::eye(3);
        assert_eq!(i3.at(1, 1), 1.0);
        assert_eq!(i3.at(0, 1), 0.0);
        assert!((i3.frob_norm() - 3f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(5), 8);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
    }
}
