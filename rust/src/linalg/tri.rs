//! Upper-triangular solves and inverses — applying the preconditioner.
//!
//! The two-step preconditioning never forms `U = AR^{-1}` (that would cost
//! O(nd^2), exactly what the paper avoids); it applies `R^{-1}`/`R^{-T}` to
//! d-vectors. These routines are O(d^2) each.

use super::matrix::Mat;

/// Solve R x = b for upper-triangular R (back substitution).
pub fn solve_upper(r: &Mat, b: &[f64]) -> Vec<f64> {
    let d = r.rows;
    assert_eq!(r.cols, d);
    assert_eq!(b.len(), d);
    let mut x = b.to_vec();
    for i in (0..d).rev() {
        let mut s = x[i];
        let row = r.row(i);
        for j in (i + 1)..d {
            s -= row[j] * x[j];
        }
        let diag = row[i];
        assert!(diag != 0.0, "singular triangular factor at {i}");
        x[i] = s / diag;
    }
    x
}

/// Solve R^T x = b for upper-triangular R (forward substitution on R^T).
pub fn solve_upper_t(r: &Mat, b: &[f64]) -> Vec<f64> {
    let d = r.rows;
    assert_eq!(r.cols, d);
    assert_eq!(b.len(), d);
    let mut x = b.to_vec();
    for i in 0..d {
        let mut s = x[i];
        for j in 0..i {
            s -= r.at(j, i) * x[j];
        }
        let diag = r.at(i, i);
        assert!(diag != 0.0, "singular triangular factor at {i}");
        x[i] = s / diag;
    }
    x
}

/// Apply the preconditioner kernel: y = R^{-1} R^{-T} g.
/// This is `pinv @ g` in the L2 graphs (pinv = R^{-1}R^{-T} = (A^T A)^{-1}
/// in exact arithmetic when R comes from a full QR of A).
pub fn apply_pinv(r: &Mat, g: &[f64]) -> Vec<f64> {
    solve_upper(r, &solve_upper_t(r, g))
}

/// Explicit R^{-1} (d x d). Needed once per job to ship the dense `pinv`
/// matrix to the PJRT artifacts; O(d^3) but d <= ~100 here.
pub fn inv_upper(r: &Mat) -> Mat {
    let d = r.rows;
    assert_eq!(r.cols, d);
    let mut inv = Mat::zeros(d, d);
    // solve R x = e_j column by column
    for j in 0..d {
        let mut e = vec![0.0; d];
        e[j] = 1.0;
        let x = solve_upper(r, &e);
        for i in 0..d {
            *inv.at_mut(i, j) = x[i];
        }
    }
    inv
}

/// Dense pinv = R^{-1} R^{-T} for the artifact inputs.
pub fn pinv_dense(r: &Mat) -> Mat {
    let rinv = inv_upper(r);
    super::blas::gemm(&rinv, &rinv.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemm, gemv};
    use crate::linalg::qr::qr_r;
    use crate::util::rng::Rng;

    fn random_upper(d: usize, rng: &mut Rng) -> Mat {
        let mut r = Mat::zeros(d, d);
        for i in 0..d {
            for j in i..d {
                *r.at_mut(i, j) = rng.gaussian();
            }
            // keep well-conditioned
            *r.at_mut(i, i) = 1.0 + rng.uniform();
        }
        r
    }

    #[test]
    fn solve_upper_roundtrip() {
        let mut rng = Rng::new(1);
        let r = random_upper(9, &mut rng);
        let x = rng.gaussians(9);
        let b = gemv(&r, &x);
        let got = solve_upper(&r, &b);
        for (u, v) in got.iter().zip(&x) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_upper_t_roundtrip() {
        let mut rng = Rng::new(2);
        let r = random_upper(7, &mut rng);
        let x = rng.gaussians(7);
        let b = gemv(&r.transpose(), &x);
        let got = solve_upper_t(&r, &b);
        for (u, v) in got.iter().zip(&x) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn inv_upper_is_inverse() {
        let mut rng = Rng::new(3);
        let r = random_upper(8, &mut rng);
        let inv = inv_upper(&r);
        let prod = gemm(&r, &inv);
        assert!(prod.max_abs_diff(&Mat::eye(8)) < 1e-10);
    }

    #[test]
    fn apply_pinv_matches_dense() {
        let mut rng = Rng::new(4);
        let r = random_upper(10, &mut rng);
        let g = rng.gaussians(10);
        let fast = apply_pinv(&r, &g);
        let dense = pinv_dense(&r);
        let want = gemv(&dense, &g);
        for (u, v) in fast.iter().zip(&want) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn pinv_from_qr_equals_normal_equation_inverse() {
        // R from QR(A) => R^{-1}R^{-T} = (A^T A)^{-1}
        let mut rng = Rng::new(5);
        let a = Mat::gaussian(60, 5, &mut rng);
        let r = qr_r(&a);
        let pinv = pinv_dense(&r);
        let ata = crate::linalg::blas::gram(&a);
        let prod = gemm(&pinv, &ata);
        assert!(prod.max_abs_diff(&Mat::eye(5)) < 1e-8);
    }

    #[test]
    #[should_panic]
    fn singular_factor_panics() {
        let mut r = Mat::eye(3);
        *r.at_mut(1, 1) = 0.0;
        solve_upper(&r, &[1.0, 1.0, 1.0]);
    }
}
