//! Level-1/2/3 dense kernels — the native backend's hot path.
//!
//! `gemm` uses register-tiled micro-kernels over cache-sized row/column
//! blocks and parallelizes across row blocks; `gemv`/`gemv_t` are unrolled
//! and parallelized for the full-gradient path `A^T(Ax - b)` which dominates
//! pwGradient/IHS. Correctness is pinned to naive reference implementations
//! in the tests and to the PJRT backend in the integration suite.

use super::matrix::Mat;
use crate::util::threadpool::{default_threads, parallel_for_each_index};

// ---------------------------------------------------------------------------
// level 1
// ---------------------------------------------------------------------------

/// Inner product `a · b`.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: breaks the dependency chain so the
    // compiler can keep 4 FMA pipes busy.
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = a.len() / 4;
    for k in 0..chunks {
        let i = k * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm `||x||_2`.
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `x *= s` in place.
pub fn scale_vec(x: &mut [f64], s: f64) {
    for v in x {
        *v *= s;
    }
}

/// Elementwise `a - b` as a new vector.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

// ---------------------------------------------------------------------------
// level 2
// ---------------------------------------------------------------------------

/// y = A x  (A: m x n, x: n) — row-parallel.
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0; a.rows];
    let threads = if a.rows * a.cols > 1 << 16 {
        default_threads()
    } else {
        1
    };
    if threads <= 1 {
        for i in 0..a.rows {
            y[i] = dot(a.row(i), x);
        }
    } else {
        let yptr = SendPtr(y.as_mut_ptr());
        let block = a.rows.div_ceil(threads * 4).max(64);
        let nblocks = a.rows.div_ceil(block);
        parallel_for_each_index(nblocks, threads, |bi| {
            let lo = bi * block;
            let hi = (lo + block).min(a.rows);
            for i in lo..hi {
                unsafe {
                    *yptr.get().add(i) = dot(a.row(i), x);
                }
            }
        });
    }
    y
}

/// y = A^T x  (A: m x n, x: m, y: n) — walks A row-wise (cache friendly) and
/// accumulates with axpy; parallel over row blocks with per-thread partials.
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let threads = if a.rows * a.cols > 1 << 16 {
        default_threads()
    } else {
        1
    };
    if threads <= 1 {
        let mut y = vec![0.0; a.cols];
        for i in 0..a.rows {
            axpy(x[i], a.row(i), &mut y);
        }
        return y;
    }
    let block = a.rows.div_ceil(threads).max(64);
    let nblocks = a.rows.div_ceil(block);
    let partials: Vec<std::sync::Mutex<Vec<f64>>> = (0..nblocks)
        .map(|_| std::sync::Mutex::new(vec![0.0; a.cols]))
        .collect();
    parallel_for_each_index(nblocks, threads, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        let mut local = partials[bi].lock().unwrap();
        for i in lo..hi {
            axpy(x[i], a.row(i), &mut local);
        }
    });
    let mut y = vec![0.0; a.cols];
    for p in &partials {
        axpy(1.0, &p.lock().unwrap(), &mut y);
    }
    y
}

/// Fused residual + transposed matvec: g = scale * A^T (A x - b).
/// THE native hot kernel for pwGradient / IHS / SVRG full passes: one walk
/// over A computes the residual, a second accumulates the gradient — both
/// row-major sequential, parallelized over row blocks.
pub fn fused_grad(a: &Mat, b: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
    assert_eq!(a.rows, b.len());
    assert_eq!(a.cols, x.len());
    let threads = if a.rows * a.cols > 1 << 16 {
        default_threads()
    } else {
        1
    };
    let block = a.rows.div_ceil(threads.max(1)).max(64);
    let nblocks = a.rows.div_ceil(block);
    let partials: Vec<std::sync::Mutex<Vec<f64>>> = (0..nblocks)
        .map(|_| std::sync::Mutex::new(vec![0.0; a.cols]))
        .collect();
    parallel_for_each_index(nblocks, threads, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        let mut local = partials[bi].lock().unwrap();
        for i in lo..hi {
            let r = dot(a.row(i), x) - b[i];
            axpy(r, a.row(i), &mut local);
        }
    });
    let mut g = vec![0.0; a.cols];
    for p in &partials {
        axpy(1.0, &p.lock().unwrap(), &mut g);
    }
    scale_vec(&mut g, scale);
    g
}

/// ||A x - b||^2 without materializing the residual vector.
pub fn residual_sq(a: &Mat, b: &[f64], x: &[f64]) -> f64 {
    assert_eq!(a.rows, b.len());
    let threads = if a.rows * a.cols > 1 << 16 {
        default_threads()
    } else {
        1
    };
    let block = a.rows.div_ceil(threads.max(1)).max(64);
    let nblocks = a.rows.div_ceil(block);
    let partials: Vec<std::sync::atomic::AtomicU64> = (0..nblocks)
        .map(|_| std::sync::atomic::AtomicU64::new(0))
        .collect();
    parallel_for_each_index(nblocks, threads, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        let mut s = 0.0;
        for i in lo..hi {
            let r = dot(a.row(i), x) - b[i];
            s += r * r;
        }
        partials[bi].store(s.to_bits(), std::sync::atomic::Ordering::Relaxed);
    });
    partials
        .iter()
        .map(|p| f64::from_bits(p.load(std::sync::atomic::Ordering::Relaxed)))
        .sum()
}

/// `||A x_k - b||^2` for a batch of iterates in one pass over `A`.
///
/// Per-column arithmetic mirrors [`residual_sq`] exactly — same thread
/// count, same row-block split, same per-row `dot(row, x_k) - b[i]` update
/// and the same in-order block merge — so column `k` of the result is
/// bitwise equal to the serial `residual_sq(a, b, &xs[k])`. The
/// fused-trials driver relies on this to keep batched execution
/// bit-identical to serial replay. The win is memory traffic: each row of
/// `A` is read once for all `k` iterates instead of `k` times.
pub fn residual_sq_multi(a: &Mat, b: &[f64], xs: &[Vec<f64>]) -> Vec<f64> {
    assert_eq!(a.rows, b.len());
    for x in xs {
        assert_eq!(a.cols, x.len());
    }
    let k = xs.len();
    if k == 0 {
        return Vec::new();
    }
    let threads = if a.rows * a.cols > 1 << 16 {
        default_threads()
    } else {
        1
    };
    let block = a.rows.div_ceil(threads.max(1)).max(64);
    let nblocks = a.rows.div_ceil(block);
    let partials: Vec<std::sync::Mutex<Vec<f64>>> = (0..nblocks)
        .map(|_| std::sync::Mutex::new(vec![0.0; k]))
        .collect();
    parallel_for_each_index(nblocks, threads, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        let mut local = partials[bi].lock().unwrap();
        for i in lo..hi {
            let row = a.row(i);
            for (sk, x) in local.iter_mut().zip(xs) {
                let r = dot(row, x) - b[i];
                *sk += r * r;
            }
        }
    });
    let mut out = vec![0.0; k];
    for p in &partials {
        for (o, s) in out.iter_mut().zip(p.lock().unwrap().iter()) {
            *o += s;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// level 3
// ---------------------------------------------------------------------------

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    #[inline]
    fn get(&self) -> *mut T {
        self.0
    }
}

/// C = A B with register-tiled 4x4 micro-kernel, row-block parallel.
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let threads = if flops > 1e6 { default_threads() } else { 1 };
    let cptr = SendPtr(c.data.as_mut_ptr());
    // row blocks sized so an (MB x k) panel of A + (k x NB) panel of B fit L2
    const MB: usize = 64;
    let nblocks = m.div_ceil(MB);
    parallel_for_each_index(nblocks, threads, |bi| {
        let i0 = bi * MB;
        let i1 = (i0 + MB).min(m);
        unsafe {
            gemm_block(a, b, cptr.get(), i0, i1, k, n);
        }
    });
    c
}

/// Compute rows [i0, i1) of C = A B into the raw pointer (each row block is
/// written by exactly one thread — no aliasing).
unsafe fn gemm_block(a: &Mat, b: &Mat, c: *mut f64, i0: usize, i1: usize, k: usize, n: usize) {
    // 4-row x full-width micro-panels: stream B once per 4 rows of A.
    let mut i = i0;
    while i + 4 <= i1 {
        let (a0, a1, a2, a3) = (a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3));
        let (c0, c1, c2, c3) = (
            std::slice::from_raw_parts_mut(c.add(i * n), n),
            std::slice::from_raw_parts_mut(c.add((i + 1) * n), n),
            std::slice::from_raw_parts_mut(c.add((i + 2) * n), n),
            std::slice::from_raw_parts_mut(c.add((i + 3) * n), n),
        );
        for p in 0..k {
            let brow = b.row(p);
            let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
            for j in 0..n {
                let bj = brow[j];
                c0[j] += v0 * bj;
                c1[j] += v1 * bj;
                c2[j] += v2 * bj;
                c3[j] += v3 * bj;
            }
        }
        i += 4;
    }
    while i < i1 {
        let ai = a.row(i);
        let ci = std::slice::from_raw_parts_mut(c.add(i * n), n);
        for p in 0..k {
            axpy(ai[p], b.row(p), ci);
        }
        i += 1;
    }
}

/// G = A^T A (d x d Gram matrix), exploiting symmetry; used for condition
/// number estimation and the exact normal-equation solver.
pub fn gram(a: &Mat) -> Mat {
    let d = a.cols;
    let mut g = Mat::zeros(d, d);
    let threads = if a.rows * d * d > 1 << 18 {
        default_threads()
    } else {
        1
    };
    let block = a.rows.div_ceil(threads.max(1)).max(128);
    let nblocks = a.rows.div_ceil(block);
    let partials: Vec<std::sync::Mutex<Mat>> = (0..nblocks)
        .map(|_| std::sync::Mutex::new(Mat::zeros(d, d)))
        .collect();
    parallel_for_each_index(nblocks, threads, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        let mut local = partials[bi].lock().unwrap();
        for i in lo..hi {
            let row = a.row(i);
            // upper triangle only
            for p in 0..d {
                let v = row[p];
                if v != 0.0 {
                    let dst = &mut local.data[p * d..(p + 1) * d];
                    for q in p..d {
                        dst[q] += v * row[q];
                    }
                }
            }
        }
    });
    for p in &partials {
        let local = p.lock().unwrap();
        for i in 0..d * d {
            g.data[i] += local.data[i];
        }
    }
    // mirror
    for p in 0..d {
        for q in (p + 1)..d {
            g.data[q * d + p] = g.data[p * d + q];
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_gemm(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for p in 0..a.cols {
                    s += a.at(i, p) * b.at(p, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(2);
        for len in [0, 1, 3, 4, 7, 64, 129] {
            let a = rng.gaussians(len);
            let b = rng.gaussians(len);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-10 * (1.0 + naive.abs()));
        }
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Rng::new(3);
        let a = Mat::gaussian(83, 17, &mut rng);
        let x = rng.gaussians(17);
        let y = gemv(&a, &x);
        for i in 0..a.rows {
            let want = dot(a.row(i), &x);
            assert!((y[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_parallel_path_matches() {
        let mut rng = Rng::new(4);
        let a = Mat::gaussian(1 << 10, 300, &mut rng); // big enough to go parallel
        let x = rng.gaussians(300);
        let y = gemv(&a, &x);
        for i in [0, 511, 1023] {
            let want = dot(a.row(i), &x);
            assert!((y[i] - want).abs() < 1e-10);
        }
    }

    #[test]
    fn gemv_t_matches_explicit_transpose() {
        let mut rng = Rng::new(5);
        let a = Mat::gaussian(400, 31, &mut rng);
        let x = rng.gaussians(400);
        let y = gemv_t(&a, &x);
        let yt = gemv(&a.transpose(), &x);
        for (u, v) in y.iter().zip(&yt) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn fused_grad_matches_composition() {
        let mut rng = Rng::new(6);
        let a = Mat::gaussian(500, 23, &mut rng);
        let b = rng.gaussians(500);
        let x = rng.gaussians(23);
        let g = fused_grad(&a, &b, &x, 2.0);
        let r = sub(&gemv(&a, &x), &b);
        let mut want = gemv_t(&a, &r);
        scale_vec(&mut want, 2.0);
        for (u, v) in g.iter().zip(&want) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn residual_sq_matches() {
        let mut rng = Rng::new(7);
        let a = Mat::gaussian(300, 11, &mut rng);
        let b = rng.gaussians(300);
        let x = rng.gaussians(11);
        let r = sub(&gemv(&a, &x), &b);
        let want: f64 = r.iter().map(|v| v * v).sum();
        assert!((residual_sq(&a, &b, &x) - want).abs() < 1e-9 * want);
    }

    #[test]
    fn residual_sq_multi_is_bitwise_per_column() {
        let mut rng = Rng::new(17);
        // small (serial, 300x11) and large (parallel, 600x120 > 1<<16)
        for (n, d) in [(300usize, 11usize), (600, 120)] {
            let a = Mat::gaussian(n, d, &mut rng);
            let b = rng.gaussians(n);
            let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.gaussians(d)).collect();
            let multi = residual_sq_multi(&a, &b, &xs);
            assert_eq!(multi.len(), 4);
            for (k, x) in xs.iter().enumerate() {
                let serial = residual_sq(&a, &b, x);
                assert_eq!(
                    multi[k].to_bits(),
                    serial.to_bits(),
                    "({n}x{d}) column {k}: {} vs {serial}",
                    multi[k]
                );
            }
        }
        assert!(residual_sq_multi(&Mat::zeros(3, 2), &[0.0; 3], &[]).is_empty());
    }

    #[test]
    fn gemm_matches_naive_small_and_odd_shapes() {
        let mut rng = Rng::new(8);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (8, 8, 8), (17, 31, 13), (65, 9, 40)] {
            let a = Mat::gaussian(m, k, &mut rng);
            let b = Mat::gaussian(k, n, &mut rng);
            let c = gemm(&a, &b);
            let want = naive_gemm(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-10, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_parallel_path_matches() {
        let mut rng = Rng::new(9);
        let a = Mat::gaussian(257, 64, &mut rng);
        let b = Mat::gaussian(64, 129, &mut rng);
        let c = gemm(&a, &b);
        let want = naive_gemm(&a, &b);
        assert!(c.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn gram_is_symmetric_and_correct() {
        let mut rng = Rng::new(10);
        let a = Mat::gaussian(200, 15, &mut rng);
        let g = gram(&a);
        let want = naive_gemm(&a.transpose(), &a);
        assert!(g.max_abs_diff(&want) < 1e-9);
        for i in 0..15 {
            for j in 0..15 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }

    #[test]
    fn axpy_and_nrm2() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
