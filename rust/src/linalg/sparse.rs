//! Compressed sparse row (CSR) matrices — the input-sparsity-time payload.
//!
//! The paper's Table 2 costs (CountSketch O(nnz(A)), sparse l2 embedding
//! O(nnz(A) log d)) only materialize when the data itself is stored sparse:
//! a 1M x 100 design at 1% density pays 100x the necessary flops through
//! the dense [`Mat`] paths — in the sketch, in every mini-batch gradient,
//! and in the full-gradient passes. `CsrMat` stores exactly the nonzeros;
//! the sketch layer streams it in O(nnz) (`sketch::apply_streamed_csr`),
//! and the stochastic solvers compute mini-batch gradients in
//! O(nnz(batch)) straight off the sparse rows ([`CsrMat::batch_grad`]).
//!
//! Layout: standard three-array CSR. Row `i`'s entries live at
//! `indices[indptr[i]..indptr[i+1]]` / `values[..]`, with column indices
//! strictly increasing within a row (the libsvm loader sorts on ingest).

use super::Mat;

/// Three-array CSR sparse matrix (see module docs for the layout contract).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// `rows + 1` monotone offsets into `indices`/`values`.
    pub indptr: Vec<usize>,
    /// Column index of each stored entry, strictly increasing per row.
    pub indices: Vec<u32>,
    /// Stored entry values (explicit zeros are allowed and preserved).
    pub values: Vec<f64>,
}

impl CsrMat {
    /// Assemble from raw CSR arrays, validating the structural invariants
    /// (monotone indptr, in-bounds sorted-per-row indices, matching
    /// lengths). Internal constructors panic on violation; the libsvm
    /// parser validates its input and returns `Err` before getting here.
    pub fn new(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> CsrMat {
        assert_eq!(indptr.len(), rows + 1, "indptr must have rows+1 entries");
        assert_eq!(indptr[0], 0);
        assert_eq!(*indptr.last().unwrap(), indices.len());
        assert_eq!(indices.len(), values.len());
        assert!(cols <= u32::MAX as usize);
        for i in 0..rows {
            assert!(indptr[i] <= indptr[i + 1], "indptr must be monotone");
            let row = &indices[indptr[i]..indptr[i + 1]];
            for w in row.windows(2) {
                assert!(w[0] < w[1], "row {i}: indices must be strictly increasing");
            }
            if let Some(&last) = row.last() {
                assert!((last as usize) < cols, "row {i}: column out of range");
            }
        }
        CsrMat {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Compress a dense matrix, dropping exact zeros.
    pub fn from_dense(a: &Mat) -> CsrMat {
        let mut indptr = Vec::with_capacity(a.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..a.rows {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMat {
            rows: a.rows,
            cols: a.cols,
            indptr,
            indices,
            values,
        }
    }

    /// Materialize the dense equivalent.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (c, v) in cols.iter().zip(vals) {
                orow[*c as usize] = *v;
            }
        }
        out
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// nnz / (rows * cols); 1.0 for an empty shape.
    pub fn density(&self) -> f64 {
        let cells = (self.rows * self.cols).max(1) as f64;
        self.nnz() as f64 / cells
    }

    /// Translate a row-count tuning knob into a per-shard nnz budget via
    /// the mean row occupancy — the ONE place `--block-rows` is given its
    /// "about this many rows per shard" meaning for CSR sharding (shared by
    /// the backend facade, the native executor's default tuning, and
    /// `Dataset::csr_blocks`).
    pub fn nnz_budget_for_rows(&self, block_rows: usize) -> usize {
        let avg = (self.nnz() / self.rows.max(1)).max(1);
        block_rows.saturating_mul(avg).max(1)
    }

    /// Row `i` as parallel (column-index, value) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Stored entries in row `i`.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// `A_i · x` in O(nnz(row)).
    #[inline]
    pub fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        let (cols, vals) = self.row(i);
        let mut s = 0.0;
        for (c, v) in cols.iter().zip(vals) {
            s += v * x[*c as usize];
        }
        s
    }

    /// `out += coef * A_i` in O(nnz(row)).
    #[inline]
    pub fn row_axpy(&self, i: usize, coef: f64, out: &mut [f64]) {
        let (cols, vals) = self.row(i);
        for (c, v) in cols.iter().zip(vals) {
            out[*c as usize] += coef * v;
        }
    }

    /// `||A x - b||^2` in O(nnz).
    pub fn residual_sq(&self, b: &[f64], x: &[f64]) -> f64 {
        assert_eq!(self.rows, b.len());
        let mut s = 0.0;
        for i in 0..self.rows {
            let r = self.row_dot(i, x) - b[i];
            s += r * r;
        }
        s
    }

    /// `||A x_k - b||^2` for a batch of iterates in one CSR pass.
    ///
    /// Per-column arithmetic (row order, `row_dot` accumulation, the
    /// `r * r` running sum) is identical to [`CsrMat::residual_sq`], so
    /// column `k` of the result is bitwise equal to the serial
    /// `residual_sq(b, &xs[k])` — the fused-trials driver's bit-identity
    /// contract depends on this.
    pub fn residual_sq_multi(&self, b: &[f64], xs: &[Vec<f64>]) -> Vec<f64> {
        assert_eq!(self.rows, b.len());
        let mut s = vec![0.0; xs.len()];
        for i in 0..self.rows {
            for (sk, x) in s.iter_mut().zip(xs) {
                let r = self.row_dot(i, x) - b[i];
                *sk += r * r;
            }
        }
        s
    }

    /// Full gradient `scale * A^T (A x - b)` in O(nnz).
    pub fn fused_grad(&self, b: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
        assert_eq!(self.rows, b.len());
        let mut g = vec![0.0; self.cols];
        for i in 0..self.rows {
            let r = self.row_dot(i, x) - b[i];
            self.row_axpy(i, r, &mut g);
        }
        for v in &mut g {
            *v *= scale;
        }
        g
    }

    /// Mini-batch gradient `scale * A_tau^T (A_tau x - b_tau)` for sampled
    /// row indices `tau` — O(nnz(batch)) instead of the dense gather's
    /// O(r d): no row copies, residual and scatter touch only stored
    /// entries. Equals `blas::fused_grad(gather(tau), b[tau], x, scale)` up
    /// to floating-point re-association.
    pub fn batch_grad(&self, tau: &[usize], b: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
        let mut g = vec![0.0; self.cols];
        for &i in tau {
            let r = self.row_dot(i, x) - b[i];
            self.row_axpy(i, r, &mut g);
        }
        for v in &mut g {
            *v *= scale;
        }
        g
    }

    /// `A^T v` in O(nnz) — the transpose product CGLS ground truth needs
    /// (never forms A^T A, never densifies).
    pub fn t_mul_vec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            self.row_axpy(i, v[i], &mut out);
        }
        out
    }

    /// The padded `[A | b]` FWHT buffer built straight from CSR in ONE
    /// allocation — the HD transform's entry point for sparse datasets, so
    /// step 2 materializes only the padded buffer it is about to transform
    /// (the FWHT densifies in its first butterfly round regardless) and
    /// never a standalone dense mirror. Mirrors `Mat::hstack_col_padded`.
    pub fn hstack_col_padded(&self, col: &[f64], rows_out: usize) -> Mat {
        assert_eq!(self.rows, col.len());
        assert!(rows_out >= self.rows);
        let d = self.cols;
        let mut out = Mat::zeros(rows_out, d + 1);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (c, v) in cols.iter().zip(vals) {
                orow[*c as usize] = *v;
            }
            orow[d] = col[i];
        }
        out
    }

    /// `A B` for a dense `cols x k` right factor — O(nnz * k). Used for the
    /// JL leverage-score projection `A (R^{-1} G)` in pwSGD.
    pub fn spmm_dense(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let k = b.cols;
        let mut out = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (c, v) in cols.iter().zip(vals) {
                let brow = b.row(*c as usize);
                for (o, bv) in orow.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Rng;

    /// Random dense matrix with ~density fraction of nonzeros.
    fn sparse_dense(n: usize, d: usize, density: f64, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| {
            if rng.uniform() < density {
                rng.gaussian()
            } else {
                0.0
            }
        })
    }

    #[test]
    fn dense_roundtrip_preserves_everything() {
        let a = sparse_dense(37, 9, 0.2, 1);
        let csr = CsrMat::from_dense(&a);
        assert_eq!(csr.to_dense(), a);
        assert!(csr.nnz() < 37 * 9);
        assert!((csr.density() - csr.nnz() as f64 / (37.0 * 9.0)).abs() < 1e-15);
    }

    #[test]
    fn row_access_and_sorted_indices() {
        let a = Mat::from_vec(2, 4, vec![0.0, 3.0, 0.0, 5.0, 1.0, 0.0, 0.0, 0.0]);
        let csr = CsrMat::from_dense(&a);
        assert_eq!(csr.nnz(), 3);
        let (c0, v0) = csr.row(0);
        assert_eq!(c0, &[1, 3]);
        assert_eq!(v0, &[3.0, 5.0]);
        assert_eq!(csr.row_nnz(1), 1);
    }

    #[test]
    fn row_dot_and_axpy_match_dense() {
        let a = sparse_dense(20, 6, 0.3, 2);
        let csr = CsrMat::from_dense(&a);
        let mut rng = Rng::new(3);
        let x = rng.gaussians(6);
        for i in 0..20 {
            let want = blas::dot(a.row(i), &x);
            assert!((csr.row_dot(i, &x) - want).abs() < 1e-12);
            let mut got = vec![1.0; 6];
            let mut ref_out = vec![1.0; 6];
            csr.row_axpy(i, 2.5, &mut got);
            blas::axpy(2.5, a.row(i), &mut ref_out);
            for (g, w) in got.iter().zip(&ref_out) {
                assert!((g - w).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gradients_and_residual_match_dense() {
        let a = sparse_dense(64, 5, 0.25, 4);
        let csr = CsrMat::from_dense(&a);
        let mut rng = Rng::new(5);
        let b = rng.gaussians(64);
        let x = rng.gaussians(5);
        let f = csr.residual_sq(&b, &x);
        let f_ref = blas::residual_sq(&a, &b, &x);
        assert!((f - f_ref).abs() < 1e-10 * (1.0 + f_ref));
        let g = csr.fused_grad(&b, &x, 2.0);
        let g_ref = blas::fused_grad(&a, &b, &x, 2.0);
        for (u, v) in g.iter().zip(&g_ref) {
            assert!((u - v).abs() < 1e-10, "{u} vs {v}");
        }
    }

    #[test]
    fn residual_sq_multi_is_bitwise_per_column() {
        let a = sparse_dense(80, 7, 0.3, 9);
        let csr = CsrMat::from_dense(&a);
        let mut rng = Rng::new(13);
        let b = rng.gaussians(80);
        let xs: Vec<Vec<f64>> = (0..3).map(|_| rng.gaussians(7)).collect();
        let multi = csr.residual_sq_multi(&b, &xs);
        for (k, x) in xs.iter().enumerate() {
            let serial = csr.residual_sq(&b, x);
            assert_eq!(multi[k].to_bits(), serial.to_bits(), "column {k}");
        }
        assert!(csr.residual_sq_multi(&b, &[]).is_empty());
    }

    #[test]
    fn batch_grad_matches_dense_gather() {
        let a = sparse_dense(64, 5, 0.3, 6);
        let csr = CsrMat::from_dense(&a);
        let mut rng = Rng::new(7);
        let b = rng.gaussians(64);
        let x = rng.gaussians(5);
        let tau = rng.indices(16, 64);
        let m = a.gather_rows(&tau);
        let v: Vec<f64> = tau.iter().map(|&i| b[i]).collect();
        let want = blas::fused_grad(&m, &v, &x, 8.0);
        let got = csr.batch_grad(&tau, &b, &x, 8.0);
        for (u, w) in got.iter().zip(&want) {
            assert!((u - w).abs() < 1e-10, "{u} vs {w}");
        }
    }

    #[test]
    fn t_mul_vec_matches_dense_transpose_product() {
        let a = sparse_dense(50, 6, 0.3, 12);
        let csr = CsrMat::from_dense(&a);
        let mut rng = Rng::new(13);
        let v = rng.gaussians(50);
        let got = csr.t_mul_vec(&v);
        let want: Vec<f64> = (0..6)
            .map(|j| (0..50).map(|i| a.at(i, j) * v[i]).sum::<f64>())
            .collect();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10, "{g} vs {w}");
        }
    }

    #[test]
    fn hstack_col_padded_matches_dense_equivalent() {
        let a = sparse_dense(37, 5, 0.3, 14);
        let csr = CsrMat::from_dense(&a);
        let mut rng = Rng::new(15);
        let b = rng.gaussians(37);
        let got = csr.hstack_col_padded(&b, 64);
        let want = a.hstack_col_padded(&b, 64);
        assert_eq!(got, want, "CSR-built padded buffer must equal the dense one");
        assert_eq!((got.rows, got.cols), (64, 6));
    }

    #[test]
    fn spmm_matches_gemm() {
        let a = sparse_dense(40, 7, 0.3, 8);
        let csr = CsrMat::from_dense(&a);
        let mut rng = Rng::new(9);
        let b = Mat::gaussian(7, 3, &mut rng);
        let got = csr.spmm_dense(&b);
        let want = blas::gemm(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn nnz_budget_scales_with_occupancy() {
        let a = sparse_dense(100, 10, 0.3, 11);
        let csr = CsrMat::from_dense(&a);
        let avg = (csr.nnz() / 100).max(1);
        assert_eq!(csr.nnz_budget_for_rows(8), 8 * avg);
        // degenerate shapes keep the budget positive
        let empty = CsrMat::new(0, 4, vec![0], vec![], vec![]);
        assert_eq!(empty.nnz_budget_for_rows(16), 16);
        assert_eq!(empty.nnz_budget_for_rows(0), 1);
    }

    #[test]
    fn explicit_zeros_survive_construction() {
        // stored zeros are legal CSR (a libsvm file may contain `3:0`)
        let csr = CsrMat::new(2, 4, vec![0, 2, 2], vec![0, 3], vec![0.0, 2.0]);
        assert_eq!(csr.nnz(), 2);
        assert_eq!(csr.row_nnz(1), 0);
        assert_eq!(csr.to_dense().row(0), &[0.0, 0.0, 0.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn unsorted_indices_rejected() {
        let _ = CsrMat::new(1, 4, vec![0, 2], vec![3, 0], vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_column_rejected() {
        let _ = CsrMat::new(1, 2, vec![0, 1], vec![5], vec![1.0]);
    }
}
