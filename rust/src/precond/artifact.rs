//! The two-step preconditioner reified as a shareable artifact.
//!
//! `precondition_with` / `hd_transform_ds_with` *compute*; this module
//! packages their outputs so acquisition can be separated from computation:
//! a [`PrecondArtifact`] is immutable, lives behind `Arc`, and can be handed
//! to any number of concurrent solves. The paper's amortization claim — one
//! sketch-QR + one Hadamard transform buys cheap iterations forever — only
//! pays off if that artifact survives the solve that built it; see
//! [`super::cache`] for the keyed LRU that keeps it alive across trials and
//! jobs.
//!
//! Construction is **memory-budgeted**: the HD step's padded buffer is the
//! one dense object a sparse dataset ever materializes, and it goes through
//! [`crate::util::mem::MemBudget`] — an over-budget request fails with a
//! structured error the serve loop reports, instead of OOM-killing a
//! worker. Step-1-only artifacts on CSR data charge (and densify) nothing.
//!
//! Two construction paths with different RNG contracts:
//!
//! * [`PrecondArtifact::compute_inline`] samples from the *caller's* rng in
//!   exactly the order the pre-driver solvers did (sketch draws, then HD
//!   signs) — the paper-fidelity path, bit-compatible with fresh-per-trial
//!   traces.
//! * [`PrecondArtifact::compute_keyed`] samples from rng streams forked
//!   deterministically from the cache key, so a cached artifact is a pure
//!   function of its key: trial rng streams never observe whether the cache
//!   was warm or cold, and the HD step can be filled in later
//!   ([`PrecondArtifact::with_hd`]) without replaying the sketch draws.

use super::cache::PrecondKey;
use super::{
    hd_implicit_ds, hd_transform_ds_with, precondition_ds_budgeted, HdTransformed, ImplicitHd,
    Precondition, Step2Mode,
};
use crate::backend::Backend;
use crate::data::{Dataset, OnDiskDesign};
use crate::linalg::{CsrMat, Mat};
use crate::prox::metric::MetricProjector;
use crate::sketch::SketchKind;
use crate::util::mem::{MemBudget, MemCharge};
use crate::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Step-2 outputs (randomized Hadamard transform of [A | b]) packaged for
/// sharing: the transformed data, the padded sampling universe, and the
/// wall-clock cost of the transform.
#[derive(Clone, Debug)]
pub struct HdParts {
    /// The transformed (padded) design HDA.
    pub hda: Mat,
    /// The transformed (padded) response HDb.
    pub hdb: Vec<f64>,
    /// Padded row count (the sampling universe size).
    pub n_pad: usize,
    /// Wall-clock cost of the transform.
    pub secs: f64,
    /// Budget charge covering the resident HD data (kept alive as long as
    /// the artifact is — a cached artifact's HD bytes stay accounted until
    /// eviction drops it). `None` when built through an uncharged entry.
    pub mem: Option<Arc<MemCharge>>,
}

/// Construction metadata: what was sampled and what it cost (Table 2).
#[derive(Clone, Copy, Debug)]
pub struct ArtifactMeta {
    /// Sketch construction sampled.
    pub sketch_kind: SketchKind,
    /// Sketch rows s.
    pub sketch_rows: usize,
    /// Wall-clock cost of the sketch application.
    pub sketch_secs: f64,
    /// Wall-clock cost of the QR factorization.
    pub qr_secs: f64,
}

/// An immutable, shareable two-step preconditioner: the triangular factor
/// `R`, its dense inverse-apply `pinv = R^{-1}R^{-T}`, the (optional)
/// HD-transformed data, and a lazily built R-metric projector shared by
/// every constrained solve that touches this artifact.
pub struct PrecondArtifact {
    /// Upper-triangular R from QR(SA).
    pub r: Mat,
    /// Dense R^{-1}R^{-T} applied to gradients (`r_inv_apply`).
    pub pinv: Mat,
    /// Step-2 transform in materialized (dense) form; `None` when only the
    /// step-1 factor was requested — or when the dataset is sparse and the
    /// transform is held implicitly instead (`hd_implicit`).
    pub hd: Option<HdParts>,
    /// Step-2 transform in implicit form (sparse datasets): just the
    /// Rademacher signs — sampled rows of `HD[A|b]` are materialized on
    /// demand from the CSR payload ([`ImplicitHd::gather_rows_csr`]).
    /// Mutually exclusive with `hd`.
    pub hd_implicit: Option<ImplicitHd>,
    /// Construction metadata (what was sampled, what it cost).
    pub meta: ArtifactMeta,
    /// Lazily built H = R^T R eigendecomposition for constrained solves —
    /// computed at most once per artifact, reused across trials/jobs.
    metric: Mutex<Option<Arc<MetricProjector>>>,
}

impl std::fmt::Debug for PrecondArtifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrecondArtifact")
            .field("d", &self.r.cols)
            .field("sketch", &self.meta.sketch_kind)
            .field("sketch_rows", &self.meta.sketch_rows)
            .field("has_hd", &self.hd.is_some())
            .field("has_hd_implicit", &self.hd_implicit.is_some())
            .field("bytes", &self.bytes())
            .finish()
    }
}

/// Whether step 2 is held implicitly under `mode`. `Repr` matches the data
/// representation (the legacy contract); `Dense` always materializes (on
/// CSR: a charged, counted densify); `Implicit` keeps the signs-only form —
/// on a *dense* dataset there is no CSR payload to gather from, so a pinned
/// implicit request degrades to the materialized form (the coordinator
/// rejects that combination up front; this keeps the direct API panic-free).
fn step2_implicit(ds: &Dataset, mode: Step2Mode) -> bool {
    match mode {
        // sparse_arith, not is_sparse: a chunked on-disk dataset holds step
        // 2 implicitly exactly like resident CSR (its gathers stream the
        // shard cache); mmapdense materializes like resident dense
        Step2Mode::Repr | Step2Mode::Implicit => ds.sparse_arith(),
        Step2Mode::Dense => false,
    }
}

impl PrecondArtifact {
    fn from_parts(
        pre: Precondition,
        hd: Option<HdTransformed>,
        hd_implicit: Option<ImplicitHd>,
    ) -> PrecondArtifact {
        PrecondArtifact {
            meta: ArtifactMeta {
                sketch_kind: pre.sketch_kind,
                sketch_rows: pre.sketch_rows,
                sketch_secs: pre.sketch_secs,
                qr_secs: pre.qr_secs,
            },
            r: pre.r,
            pinv: pre.pinv,
            hd: hd.map(|h| HdParts {
                hda: h.hda,
                hdb: h.hdb,
                n_pad: h.n_pad,
                secs: h.secs,
                mem: h.mem,
            }),
            hd_implicit,
            metric: Mutex::new(None),
        }
    }

    /// Paper-fidelity construction: consume `rng` exactly as the pre-driver
    /// solvers did (sketch first, then HD signs when `with_hd`). Sparse
    /// datasets route the sketch through the O(nnz) CSR pipeline; the HD
    /// transform charges its padded buffer against `budget` and — on CSR —
    /// builds it straight from the sparse payload (no dense mirror, see
    /// DESIGN.md §11). Over budget: `Err`, with the sketch draws already
    /// consumed (the failed solve is abandoned anyway).
    #[allow(clippy::too_many_arguments)]
    pub fn compute_inline(
        backend: &Backend,
        ds: &Dataset,
        kind: SketchKind,
        sketch_rows: usize,
        rng: &mut Rng,
        block_rows: Option<usize>,
        with_hd: bool,
        step2: Step2Mode,
        budget: &Arc<MemBudget>,
    ) -> anyhow::Result<PrecondArtifact> {
        let pre =
            precondition_ds_budgeted(backend, ds, kind, sketch_rows, rng, block_rows, budget)?;
        let (hd, hd_implicit) = if with_hd {
            if step2_implicit(ds, step2) {
                // implicit step 2: same sign draws, zero densify, zero
                // charge — the padded buffer is never built
                (None, Some(hd_implicit_ds(ds, rng)))
            } else {
                let stage = format!("hd_transform[{}]", ds.name);
                (Some(hd_transform_ds_with(backend, ds, rng, budget, &stage)?), None)
            }
        } else {
            (None, None)
        };
        Ok(PrecondArtifact::from_parts(pre, hd, hd_implicit))
    }

    /// Independent rng streams derived from the cache key: forking in a
    /// fixed order keeps the HD stream reconstructible without replaying
    /// the sketch draws (see [`PrecondArtifact::with_hd`]).
    fn keyed_rngs(key: &PrecondKey) -> (Rng, Rng) {
        let mut base = Rng::new(key.seed ^ 0xA87F_1C3E_5D2B_9E01);
        let sketch_rng = base.fork(1);
        let hd_rng = base.fork(2);
        (sketch_rng, hd_rng)
    }

    /// Cache-keyed construction: the artifact is a pure function of
    /// `(dataset, key)` — no caller rng state is consumed, so trial streams
    /// are identical whether this ran or a cached copy was returned.
    #[allow(clippy::too_many_arguments)]
    pub fn compute_keyed(
        backend: &Backend,
        ds: &Dataset,
        key: &PrecondKey,
        block_rows: Option<usize>,
        with_hd: bool,
        step2: Step2Mode,
        budget: &Arc<MemBudget>,
    ) -> anyhow::Result<PrecondArtifact> {
        let (mut sketch_rng, mut hd_rng) = PrecondArtifact::keyed_rngs(key);
        let pre = precondition_ds_budgeted(
            backend,
            ds,
            key.sketch,
            key.sketch_rows,
            &mut sketch_rng,
            block_rows,
            budget,
        )?;
        let (hd, hd_implicit) = if with_hd {
            if step2_implicit(ds, step2) {
                (None, Some(hd_implicit_ds(ds, &mut hd_rng)))
            } else {
                let stage = format!("hd_transform[{}]", ds.name);
                (
                    Some(hd_transform_ds_with(backend, ds, &mut hd_rng, budget, &stage)?),
                    None,
                )
            }
        } else {
            (None, None)
        };
        Ok(PrecondArtifact::from_parts(pre, hd, hd_implicit))
    }

    /// Upgrade a step-1-only cached artifact with the HD transform, reusing
    /// R/pinv (and any already-built metric projector). The HD stream comes
    /// from the key, so the result equals what [`compute_keyed`] with
    /// `with_hd = true` would have produced.
    ///
    /// [`compute_keyed`]: PrecondArtifact::compute_keyed
    pub fn with_hd(
        &self,
        backend: &Backend,
        ds: &Dataset,
        key: &PrecondKey,
        step2: Step2Mode,
        budget: &Arc<MemBudget>,
    ) -> anyhow::Result<PrecondArtifact> {
        let (_, mut hd_rng) = PrecondArtifact::keyed_rngs(key);
        let (hd, hd_implicit) = if step2_implicit(ds, step2) {
            (None, Some(hd_implicit_ds(ds, &mut hd_rng)))
        } else {
            let stage = format!("hd_transform[{}]", ds.name);
            let hd = hd_transform_ds_with(backend, ds, &mut hd_rng, budget, &stage)?;
            (
                Some(HdParts {
                    hda: hd.hda,
                    hdb: hd.hdb,
                    n_pad: hd.n_pad,
                    secs: hd.secs,
                    mem: hd.mem,
                }),
                None,
            )
        };
        Ok(PrecondArtifact {
            r: self.r.clone(),
            pinv: self.pinv.clone(),
            hd,
            hd_implicit,
            meta: self.meta,
            metric: Mutex::new(self.metric.lock().unwrap().clone()),
        })
    }

    /// Whether step 2 is present in either form — the acquisition layer's
    /// "does this artifact satisfy `with_hd`" check.
    pub fn has_step2(&self) -> bool {
        self.hd.is_some() || self.hd_implicit.is_some()
    }

    /// Borrow step 2 as a uniform row-sampling view: dense artifacts hand
    /// out gathers of the materialized `HD[A|b]`; sparse artifacts
    /// materialize sampled rows on demand from `ds`'s CSR payload. `None`
    /// when the artifact is step-1-only.
    pub fn hd_view<'a>(&'a self, ds: &'a Dataset) -> Option<HdView<'a>> {
        if let Some(h) = &self.hd {
            return Some(HdView::Dense(h));
        }
        self.hd_implicit.as_ref().map(|h| match ds.on_disk() {
            Some(od) => HdView::ImplicitOnDisk { hd: h, od },
            None => HdView::Implicit {
                hd: h,
                a: ds.csr().expect("implicit HD artifact requires a CSR dataset"),
                b: &ds.b,
            },
        })
    }

    /// The shared R-metric projector (Step-6 quadratic subproblem), built on
    /// first use and reused by every constrained solve on this artifact.
    pub fn metric(&self) -> Arc<MetricProjector> {
        let mut guard = self.metric.lock().unwrap();
        if let Some(m) = &*guard {
            return Arc::clone(m);
        }
        let m = Arc::new(MetricProjector::from_r(&self.r));
        *guard = Some(Arc::clone(&m));
        m
    }

    /// Resident size for the cache's byte-budget accounting. Always
    /// reserves space for the lazily built metric projector (~d^2 + d
    /// doubles: eigenvectors + eigenvalues) — it is attached *after*
    /// insertion by the first constrained solve, and the cache cannot
    /// re-account an entry, so budgeting the worst case up front keeps
    /// constrained workloads inside `HDPW_PRECOND_CACHE_MB`.
    pub fn bytes(&self) -> usize {
        let hd = self
            .hd
            .as_ref()
            .map(|h| h.hda.data.len() + h.hdb.len())
            .unwrap_or(0);
        let hd_implicit = self
            .hd_implicit
            .as_ref()
            .map(|h| h.signs.len())
            .unwrap_or(0);
        let d = self.r.cols;
        let metric_reserve = d * d + d;
        (self.r.data.len() + self.pinv.data.len() + hd + hd_implicit + metric_reserve)
            * std::mem::size_of::<f64>()
            + 128
    }
}

/// A uniform borrow-view over step 2: the mini-batch solvers only ever
/// *gather sampled rows* of `HD[A|b]`, so this is the whole interface —
/// dense artifacts gather from the materialized transform, implicit
/// (sparse) artifacts evaluate the sampled rows on demand in
/// input-sparsity time. Keeping the solvers on this view is what lets the
/// HD family run on CSR with zero densify events.
pub enum HdView<'a> {
    /// Materialized step 2 (dense datasets): gathers are row copies.
    Dense(&'a HdParts),
    /// Implicit step 2 (sparse datasets): gathers are O(nnz + n) signed
    /// scatter passes per sampled row.
    Implicit {
        /// The sign vector + padded universe.
        hd: &'a ImplicitHd,
        /// The CSR design the rows are evaluated from.
        a: &'a CsrMat,
        /// The (untransformed) response vector.
        b: &'a [f64],
    },
    /// Implicit step 2 over a chunked on-disk design: gathers stream the
    /// CSR payload shard by shard through the block cache
    /// ([`ImplicitHd::gather_rows_ondisk_blocked`]) — one file pass per
    /// batch, bitwise the resident implicit gather's bits, and fallible
    /// like every disk access.
    ImplicitOnDisk {
        /// The sign vector + padded universe.
        hd: &'a ImplicitHd,
        /// The disk-backed design the rows are evaluated from.
        od: &'a OnDiskDesign,
    },
}

impl HdView<'_> {
    /// The padded sampling universe `n_pad`.
    pub fn n_pad(&self) -> usize {
        match self {
            HdView::Dense(h) => h.n_pad,
            HdView::Implicit { hd, .. } => hd.n_pad,
            HdView::ImplicitOnDisk { hd, .. } => hd.n_pad,
        }
    }

    /// Materialize rows `idx` of `HD[A|b]` as a `idx.len() x d` design
    /// block plus the matching responses, with the default sampled-row tile
    /// ([`super::GATHER_BLOCK`]) on the implicit path. Fallible because the
    /// on-disk view reads shards; resident views never return `Err`.
    pub fn gather(&self, idx: &[usize]) -> anyhow::Result<(Mat, Vec<f64>)> {
        self.gather_blocked(idx, 0)
    }

    /// [`HdView::gather`] with an explicit sampled-row tile size for the
    /// implicit (CSR) path — the step rules pass their mini-batch size so
    /// one blockwise pass over the CSR payload covers the whole batch
    /// (`block = 0` means the [`super::GATHER_BLOCK`] default). Dense
    /// gathers are plain row copies and ignore the knob.
    pub fn gather_blocked(&self, idx: &[usize], block: usize) -> anyhow::Result<(Mat, Vec<f64>)> {
        match self {
            HdView::Dense(h) => Ok((
                h.hda.gather_rows(idx),
                idx.iter().map(|&i| h.hdb[i]).collect(),
            )),
            HdView::Implicit { hd, a, b } => Ok(hd.gather_rows_csr_blocked(a, b, idx, block)),
            HdView::ImplicitOnDisk { hd, od } => hd.gather_rows_ondisk_blocked(od, idx, block),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::precond::{hd_transform_with, precondition_with};

    fn ds(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        Dataset::dense("t", a, b, None)
    }

    fn key(seed: u64) -> PrecondKey {
        PrecondKey {
            dataset_id: "t".into(),
            sketch: SketchKind::CountSketch,
            sketch_rows: 120,
            seed,
            block_rows: 0,
            backend: "native".into(),
            repr: "dense".into(),
        }
    }

    fn unlimited() -> Arc<MemBudget> {
        MemBudget::unlimited()
    }

    #[test]
    fn inline_matches_legacy_rng_consumption() {
        // compute_inline must consume the caller rng exactly like the
        // hand-rolled precondition + hd_transform sequence it replaced.
        let d = ds(512, 6, 1);
        let be = Backend::native();
        let mut r1 = Rng::new(42);
        let a_ref = d.dense_if_ready().unwrap();
        let pre = precondition_with(&be, a_ref, SketchKind::CountSketch, 120, &mut r1, None);
        let hd = hd_transform_with(&be, a_ref, &d.b, &mut r1);
        let mut r2 = Rng::new(42);
        let art = PrecondArtifact::compute_inline(
            &be,
            &d,
            SketchKind::CountSketch,
            120,
            &mut r2,
            None,
            true,
            Step2Mode::Repr,
            &unlimited(),
        )
        .unwrap();
        assert_eq!(art.r.max_abs_diff(&pre.r), 0.0);
        let ahd = art.hd.as_ref().unwrap();
        assert_eq!(ahd.n_pad, hd.n_pad);
        assert_eq!(ahd.hdb, hd.hdb);
        assert_eq!(ahd.hda.max_abs_diff(&hd.hda), 0.0);
        // both rngs end in the same state
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn keyed_is_a_pure_function_of_the_key() {
        let d = ds(300, 5, 2);
        let be = Backend::native();
        let budget = unlimited();
        let a1 = PrecondArtifact::compute_keyed(&be, &d, &key(9), None, true, Step2Mode::Repr, &budget).unwrap();
        let a2 = PrecondArtifact::compute_keyed(&be, &d, &key(9), None, true, Step2Mode::Repr, &budget).unwrap();
        assert_eq!(a1.r.max_abs_diff(&a2.r), 0.0);
        assert_eq!(
            a1.hd.as_ref().unwrap().hda.max_abs_diff(&a2.hd.as_ref().unwrap().hda),
            0.0
        );
        // a different key seed samples a different sketch
        let a3 = PrecondArtifact::compute_keyed(&be, &d, &key(10), None, false, Step2Mode::Repr, &budget).unwrap();
        assert!(a3.r.max_abs_diff(&a1.r) > 0.0);
    }

    #[test]
    fn with_hd_upgrade_equals_direct_keyed_compute() {
        let d = ds(300, 5, 3);
        let be = Backend::native();
        let budget = unlimited();
        let k = key(4);
        let plain = PrecondArtifact::compute_keyed(&be, &d, &k, None, false, Step2Mode::Repr, &budget).unwrap();
        assert!(plain.hd.is_none());
        let upgraded = plain.with_hd(&be, &d, &k, Step2Mode::Repr, &budget).unwrap();
        let direct = PrecondArtifact::compute_keyed(&be, &d, &k, None, true, Step2Mode::Repr, &budget).unwrap();
        assert_eq!(upgraded.r.max_abs_diff(&direct.r), 0.0);
        let (u, v) = (upgraded.hd.as_ref().unwrap(), direct.hd.as_ref().unwrap());
        assert_eq!(u.n_pad, v.n_pad);
        assert_eq!(u.hdb, v.hdb);
        assert_eq!(u.hda.max_abs_diff(&v.hda), 0.0);
    }

    #[test]
    fn hd_bytes_stay_charged_while_artifact_lives() {
        let d = ds(300, 5, 7);
        let be = Backend::native();
        let budget = unlimited();
        let art =
            PrecondArtifact::compute_keyed(&be, &d, &key(5), None, true, Step2Mode::Repr, &budget).unwrap();
        let n_pad = 300usize.next_power_of_two();
        assert_eq!(budget.used(), n_pad * 6 * 8, "HD buffer stays accounted");
        drop(art);
        assert_eq!(budget.used(), 0, "released with the artifact");
    }

    #[test]
    fn over_budget_hd_is_a_structured_error() {
        let d = ds(512, 6, 8);
        let be = Backend::native();
        let tight = MemBudget::with_limit_mb(1);
        let _hog = tight.try_charge((1 << 20) - 128, "hog").unwrap();
        let mut rng = Rng::new(1);
        let err = PrecondArtifact::compute_inline(
            &be,
            &d,
            SketchKind::CountSketch,
            120,
            &mut rng,
            None,
            true,
            Step2Mode::Repr,
            &tight,
        )
        .unwrap_err();
        assert!(err.to_string().contains("memory budget exceeded"), "{err}");
        // a step-1-only request charges nothing and cannot fail
        let mut rng2 = Rng::new(1);
        let art = PrecondArtifact::compute_inline(
            &be,
            &d,
            SketchKind::CountSketch,
            120,
            &mut rng2,
            None,
            false,
            Step2Mode::Repr,
            &tight,
        )
        .unwrap();
        assert!(art.hd.is_none());
    }

    #[test]
    fn implicit_gather_matches_dense_transform_rows() {
        // same key on the dense and CSR copies of one dataset: the implicit
        // view must reproduce the materialized HD rows up to fp
        // re-association, while charging nothing and never densifying
        let mut rng = Rng::new(17);
        let a = Mat::from_fn(300, 5, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(300);
        let dense = Dataset::dense("t", a.clone(), b.clone(), None);
        let sparse = Dataset::from_csr("t", CsrMat::from_dense(&a), b, None);
        let be = Backend::native();
        let k = key(12);
        let bud_d = unlimited();
        let bud_s = unlimited();
        let ad = PrecondArtifact::compute_keyed(&be, &dense, &k, None, true, Step2Mode::Repr, &bud_d)
            .unwrap();
        let asp =
            PrecondArtifact::compute_keyed(&be, &sparse, &k, None, true, Step2Mode::Repr, &bud_s)
                .unwrap();
        assert!(ad.hd.is_some() && ad.hd_implicit.is_none());
        assert!(asp.hd.is_none() && asp.hd_implicit.is_some());
        assert!(asp.has_step2());
        assert_eq!(bud_s.used(), 0, "implicit step 2 charges nothing");
        assert_eq!(bud_s.densify_events(), 0);
        let vd = ad.hd_view(&dense).unwrap();
        let vs = asp.hd_view(&sparse).unwrap();
        assert_eq!(vd.n_pad(), vs.n_pad());
        let idx = vec![0usize, 3, 17, 255, vd.n_pad() - 1];
        let (md, bd) = vd.gather(&idx).unwrap();
        let (ms, bs) = vs.gather(&idx).unwrap();
        for r in 0..idx.len() {
            assert!(
                (bd[r] - bs[r]).abs() < 1e-10 * (1.0 + bd[r].abs()),
                "hdb row {r}: {} vs {}",
                bd[r],
                bs[r]
            );
            for c in 0..5 {
                let (u, v) = (md.at(r, c), ms.at(r, c));
                assert!(
                    (u - v).abs() < 1e-10 * (1.0 + u.abs()),
                    "hda ({r},{c}): {u} vs {v}"
                );
            }
        }
    }

    #[test]
    fn dense_pinned_step2_on_csr_materializes_and_charges() {
        // step2 = Dense on a CSR dataset: the artifact must hold the same
        // materialized HD[A|b] the dense copy of the data produces (same
        // keyed rng stream), charge the padded buffer, and count the
        // densify — the explicit opt-out of the zero-densify contract.
        let mut rng = Rng::new(21);
        let a = Mat::from_fn(300, 5, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(300);
        let dense = Dataset::dense("t", a.clone(), b.clone(), None);
        let sparse = Dataset::from_csr("t", CsrMat::from_dense(&a), b, None);
        let be = Backend::native();
        let k = key(12);
        let bud_d = unlimited();
        let bud_s = unlimited();
        let ad = PrecondArtifact::compute_keyed(&be, &dense, &k, None, true, Step2Mode::Repr, &bud_d)
            .unwrap();
        let asp =
            PrecondArtifact::compute_keyed(&be, &sparse, &k, None, true, Step2Mode::Dense, &bud_s)
                .unwrap();
        assert!(asp.hd.is_some() && asp.hd_implicit.is_none());
        let (u, v) = (ad.hd.as_ref().unwrap(), asp.hd.as_ref().unwrap());
        assert_eq!(u.n_pad, v.n_pad);
        let n_pad = 300usize.next_power_of_two();
        assert_eq!(bud_s.used(), n_pad * 6 * 8, "padded buffer is charged");
        assert!(bud_s.densify_events() > 0, "dense pin is a counted densify");
        // the materialized rows agree with the dense-data transform up to
        // fp re-association of the padded FWHT input
        for r in [0usize, 3, 17, 255] {
            for c in 0..5 {
                let (x, y) = (u.hda.at(r, c), v.hda.at(r, c));
                assert!((x - y).abs() < 1e-10 * (1.0 + x.abs()), "({r},{c}): {x} vs {y}");
            }
        }
        // an implicit pin on dense data degrades to the materialized form
        // instead of panicking at gather time
        let pinned =
            PrecondArtifact::compute_keyed(&be, &dense, &k, None, true, Step2Mode::Implicit, &bud_d)
                .unwrap();
        assert!(pinned.hd.is_some() && pinned.hd_implicit.is_none());
    }

    #[test]
    fn gather_blocked_matches_default_gather() {
        let mut rng = Rng::new(23);
        let a = Mat::from_fn(200, 4, |_, _| {
            if rng.uniform() < 0.25 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(200);
        let sparse = Dataset::from_csr("t", CsrMat::from_dense(&a), b, None);
        let be = Backend::native();
        let art =
            PrecondArtifact::compute_keyed(&be, &sparse, &key(3), None, true, Step2Mode::Repr, &unlimited())
                .unwrap();
        let v = art.hd_view(&sparse).unwrap();
        let idx = vec![0usize, 7, 31, 200, 255];
        let (m0, b0) = v.gather(&idx).unwrap();
        for block in [1usize, 3, 5, 64] {
            let (m, bb) = v.gather_blocked(&idx, block).unwrap();
            assert_eq!(m.max_abs_diff(&m0), 0.0, "block {block}");
            assert_eq!(bb, b0, "block {block}");
        }
    }

    #[test]
    fn metric_is_built_once_and_shared() {
        let d = ds(256, 4, 5);
        let be = Backend::native();
        let art =
            PrecondArtifact::compute_keyed(&be, &d, &key(1), None, false, Step2Mode::Repr, &unlimited())
                .unwrap();
        let m1 = art.metric();
        let m2 = art.metric();
        assert!(Arc::ptr_eq(&m1, &m2));
        // and it projects consistently with a fresh projector
        let z = vec![3.0, -2.0, 1.0, 0.5];
        let cons = crate::constraints::L2Ball { radius: 0.5 };
        let fresh = MetricProjector::from_r(&art.r);
        let a = m1.project(&z, &cons);
        let b = fresh.project(&z, &cons);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn bytes_accounts_for_hd_payload() {
        let d = ds(256, 4, 6);
        let be = Backend::native();
        let budget = unlimited();
        let plain = PrecondArtifact::compute_keyed(&be, &d, &key(2), None, false, Step2Mode::Repr, &budget).unwrap();
        let full = PrecondArtifact::compute_keyed(&be, &d, &key(2), None, true, Step2Mode::Repr, &budget).unwrap();
        assert!(full.bytes() > plain.bytes());
        // hd payload dominates: n_pad x (d) + n_pad doubles
        let hd = full.hd.as_ref().unwrap();
        assert!(full.bytes() - plain.bytes() == (hd.hda.data.len() + hd.hdb.len()) * 8);
        // sanity: the preconditioner actually conditions
        let g = blas::gram(d.dense_if_ready().unwrap());
        let kappa = crate::linalg::eigen::cond_preconditioned(&g, &full.r);
        assert!(kappa < 5.0, "kappa {kappa}");
    }
}
