//! Keyed LRU cache for [`PrecondArtifact`]s, bounded by a byte budget.
//!
//! The paper's two-step preconditioning amortizes: O(nnz + d^3) setup buys
//! O(1)-conditioned iterations forever. The service throws that away if
//! every trial recomputes setup, so the coordinator keeps one process-wide
//! `PrecondCache` beside the dataset cache. Keys capture everything the
//! artifact is a function of — `(dataset_id, sketch kind, sketch rows,
//! artifact seed, block_rows, backend kind)`; the thread count is fixed per
//! backend, so within one coordinator the key fully determines the bits.
//! Misses are single-flight: concurrent identical jobs elect one computer
//! and the rest wait, so the O(nnz + d^3) setup runs once.
//!
//! Eviction is LRU by a configurable byte budget (`HDPW_PRECOND_CACHE_MB`,
//! default 256 MiB). The budget is honored down to a *single* artifact: the
//! most recently inserted entry is never evicted, so one oversize artifact
//! still caches (bounded by one artifact's size, which is bounded by the
//! dataset the operator already chose to hold in memory).
//!
//! Hit/miss/eviction counters are exposed so dashboards can tell a cold
//! cache from a broken one (all-miss forever = broken keying).

use super::artifact::PrecondArtifact;
use crate::sketch::SketchKind;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Everything a cached preconditioner is a function of.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PrecondKey {
    /// Coordinator dataset identity (name + scale + normalize + data seed).
    pub dataset_id: String,
    /// Sketch construction the artifact was sampled with.
    pub sketch: SketchKind,
    /// Sketch rows s.
    pub sketch_rows: usize,
    /// Artifact sampling seed — the *job* seed, not a per-trial fork, so
    /// all trials of a job (and identical jobs) share one artifact.
    pub seed: u64,
    /// Row-shard height used during setup (0 = heuristic); different shard
    /// sizes re-associate the fold, so they key distinct artifacts.
    pub block_rows: usize,
    /// Backend kind the artifact was computed on ("native" | "pjrt"):
    /// per-request executors must not alias each other's numerics.
    pub backend: String,
    /// Data representation the artifact was computed from ("dense" |
    /// "csr"): the CSR fold re-associates the sketch sum, so dense and
    /// sparse artifacts for the same dataset must never alias.
    pub repr: String,
}

/// How a solve acquired its preconditioner (reported per solve).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CacheOutcome {
    /// No cache in play (reuse disabled, or a solver without a precond step).
    #[default]
    Off,
    /// Cache consulted, artifact computed and inserted.
    Miss,
    /// Artifact served from the cache (setup collapses to the lookup cost).
    Hit,
    /// Step 1 (sketch-QR) reused from the cache, but the HD transform had
    /// to be computed and filled in — cheaper than a miss, dearer than a
    /// hit; reported distinctly so "hit == lookup cost" stays true.
    Upgrade,
}

impl CacheOutcome {
    /// Wire form ("off" | "miss" | "hit" | "upgrade").
    pub fn as_str(self) -> &'static str {
        match self {
            CacheOutcome::Off => "off",
            CacheOutcome::Miss => "miss",
            CacheOutcome::Hit => "hit",
            CacheOutcome::Upgrade => "upgrade",
        }
    }
}

#[derive(Default)]
struct Inner {
    map: HashMap<PrecondKey, Arc<PrecondArtifact>>,
    /// LRU order: front = coldest, back = most recently used.
    order: Vec<PrecondKey>,
    bytes: usize,
    /// Keys currently being computed (single-flight): concurrent identical
    /// requests wait for the first compute instead of duplicating it.
    in_flight: HashSet<PrecondKey>,
}

/// Byte-budgeted LRU of shared preconditioner artifacts.
///
/// The single-flight claim here is also the engine behind the scheduler's
/// request coalescing: every concurrent same-key job beyond the first
/// blocks in [`PrecondCache::wait_for`] and adopts the one computed
/// artifact, so a coalesced batch pays for exactly one setup.
pub struct PrecondCache {
    budget: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
    inserts: AtomicUsize,
    /// Times a caller actually blocked in `wait_for` behind an in-flight
    /// compute — the "setup computations saved by coalescing" signal
    /// (hits measure reuse over time; this measures concurrent sharing).
    wait_joins: AtomicUsize,
}

/// Result of a single-flight lookup.
pub enum Lookup<'a> {
    /// Cached artifact (recency refreshed, hit counted).
    Found(Arc<PrecondArtifact>),
    /// Nothing cached and nobody computing: the caller owns the compute
    /// (miss counted). Publish the result or the claim is abandoned on drop.
    Claimed(ComputeClaim<'a>),
    /// Another caller is computing this key: `wait_for` it, then retry.
    Busy,
}

/// RAII claim on a key being computed. Dropping without `publish` (panic,
/// bail-out) releases the key so a waiter can re-claim instead of hanging.
pub struct ComputeClaim<'a> {
    cache: &'a PrecondCache,
    key: Option<PrecondKey>,
}

impl ComputeClaim<'_> {
    /// Insert the computed artifact and wake waiters.
    pub fn publish(mut self, art: Arc<PrecondArtifact>) {
        let key = self.key.take().expect("claim published once");
        self.cache.insert(key.clone(), art);
        self.cache.clear_in_flight(&key);
    }
}

impl Drop for ComputeClaim<'_> {
    fn drop(&mut self) {
        if let Some(key) = self.key.take() {
            self.cache.clear_in_flight(&key);
        }
    }
}

impl std::fmt::Debug for PrecondCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrecondCache")
            .field("budget", &self.budget)
            .field("entries", &self.entries())
            .field("bytes", &self.bytes())
            .field("hits", &self.hits())
            .field("misses", &self.misses())
            .field("evictions", &self.evictions())
            .finish()
    }
}

impl PrecondCache {
    /// A cache bounded by `budget_bytes` (floored at one byte).
    pub fn new(budget_bytes: usize) -> PrecondCache {
        PrecondCache {
            budget: budget_bytes.max(1),
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            inserts: AtomicUsize::new(0),
            wait_joins: AtomicUsize::new(0),
        }
    }

    /// Budget from `HDPW_PRECOND_CACHE_MB` (default 256 MiB).
    pub fn default_budget() -> usize {
        std::env::var("HDPW_PRECOND_CACHE_MB")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(256)
            .saturating_mul(1 << 20)
            .max(1)
    }

    /// A cache with the [`PrecondCache::default_budget`] byte budget.
    pub fn with_default_budget() -> PrecondCache {
        PrecondCache::new(PrecondCache::default_budget())
    }

    /// Evict the coldest entry — the coordinator's memory-pressure
    /// shedding hook (admission control calls this when a job's
    /// materialization would not fit while cached artifacts pin budget
    /// bytes). Unlike insert-driven eviction this may remove the newest
    /// (only) entry: under memory pressure an idle artifact is worth less
    /// than an admittable job. Returns false when the cache is empty.
    /// Counted in the eviction counter like any other eviction.
    pub fn evict_coldest(&self) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.order.is_empty() {
            return false;
        }
        let victim = g.order.remove(0);
        if let Some(a) = g.map.remove(&victim) {
            g.bytes = g.bytes.saturating_sub(a.bytes());
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        true
    }

    /// Counter-neutral peek for the coordinator's admission control:
    /// whether `key` is resident, and with its HD parts. Touches neither
    /// the hit/miss counters nor the LRU order — the dashboards' cache
    /// health must reflect solves, not admission probes.
    pub fn peek_has_hd(&self, key: &PrecondKey) -> Option<bool> {
        let g = self.inner.lock().unwrap();
        g.map.get(key).map(|a| a.hd.is_some())
    }

    /// Look up an artifact; records a hit (and refreshes recency) or a miss.
    pub fn get(&self, key: &PrecondKey) -> Option<Arc<PrecondArtifact>> {
        let mut g = self.inner.lock().unwrap();
        match g.map.get(key).cloned() {
            Some(art) => {
                if let Some(p) = g.order.iter().position(|k| k == key) {
                    let k = g.order.remove(p);
                    g.order.push(k);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(art)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Single-flight lookup: at most one caller computes a given key at a
    /// time. Waiters (`Busy`) block on [`PrecondCache::wait_for`] and then
    /// retry — they count a *hit* when the published artifact arrives, so
    /// concurrent identical jobs record exactly one miss.
    pub fn lookup_or_claim(&self, key: &PrecondKey) -> Lookup<'_> {
        let mut g = self.inner.lock().unwrap();
        if let Some(art) = g.map.get(key).cloned() {
            if let Some(p) = g.order.iter().position(|k| k == key) {
                let k = g.order.remove(p);
                g.order.push(k);
            }
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Lookup::Found(art);
        }
        if g.in_flight.contains(key) {
            return Lookup::Busy;
        }
        g.in_flight.insert(key.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Lookup::Claimed(ComputeClaim {
            cache: self,
            key: Some(key.clone()),
        })
    }

    /// Block until `key` is no longer being computed (published or
    /// abandoned), then return so the caller can retry `lookup_or_claim`.
    /// Counts one wait-join when the caller actually blocks — the number of
    /// setup computations concurrent sharing (request coalescing) saved.
    pub fn wait_for(&self, key: &PrecondKey) {
        let mut g = self.inner.lock().unwrap();
        if g.in_flight.contains(key) {
            self.wait_joins.fetch_add(1, Ordering::Relaxed);
        }
        while g.in_flight.contains(key) {
            g = self.cv.wait(g).unwrap();
        }
    }

    fn clear_in_flight(&self, key: &PrecondKey) {
        let mut g = self.inner.lock().unwrap();
        g.in_flight.remove(key);
        drop(g);
        self.cv.notify_all();
    }

    /// Insert (or replace) an artifact, then evict cold entries until the
    /// byte budget is met — never evicting the entry just inserted.
    pub fn insert(&self, key: PrecondKey, art: Arc<PrecondArtifact>) {
        let added = art.bytes();
        let mut g = self.inner.lock().unwrap();
        if let Some(old) = g.map.insert(key.clone(), art) {
            g.bytes = g.bytes.saturating_sub(old.bytes());
            if let Some(p) = g.order.iter().position(|k| k == &key) {
                g.order.remove(p);
            }
        }
        g.bytes += added;
        g.order.push(key);
        self.inserts.fetch_add(1, Ordering::Relaxed);
        while g.bytes > self.budget && g.order.len() > 1 {
            let victim = g.order.remove(0);
            if let Some(a) = g.map.remove(&victim) {
                g.bytes = g.bytes.saturating_sub(a.bytes());
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to compute.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries removed to honor the byte budget (or shed under pressure).
    pub fn evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Total inserts (including same-key replacements).
    pub fn inserts(&self) -> usize {
        self.inserts.load(Ordering::Relaxed)
    }

    /// Callers that blocked behind another caller's in-flight compute of
    /// the same key (setups saved by concurrent sharing / coalescing).
    pub fn wait_joins(&self) -> usize {
        self.wait_joins.load(Ordering::Relaxed)
    }

    /// Artifacts currently resident.
    pub fn entries(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Bytes currently resident.
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// One-line stats for the metrics snapshot / dashboards.
    pub fn snapshot(&self) -> String {
        format!(
            "precond_cache: hits={} misses={} evictions={} entries={} bytes={}/{}",
            self.hits(),
            self.misses(),
            self.evictions(),
            self.entries(),
            self.bytes(),
            self.budget
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::data::Dataset;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    fn artifact(seed: u64, with_hd: bool) -> Arc<PrecondArtifact> {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(256, 4, &mut rng);
        let b = rng.gaussians(256);
        let ds = Dataset::dense("t", a, b, None);
        Arc::new(
            PrecondArtifact::compute_keyed(
                &Backend::native(),
                &ds,
                &key(seed),
                None,
                with_hd,
                crate::precond::Step2Mode::Repr,
                &crate::util::mem::MemBudget::unlimited(),
            )
            .unwrap(),
        )
    }

    fn key(seed: u64) -> PrecondKey {
        PrecondKey {
            dataset_id: format!("ds{seed}"),
            sketch: SketchKind::CountSketch,
            sketch_rows: 64,
            seed,
            block_rows: 0,
            backend: "native".into(),
            repr: "dense".into(),
        }
    }

    #[test]
    fn hit_miss_counters_and_lru_refresh() {
        let cache = PrecondCache::new(1 << 30);
        assert!(cache.get(&key(1)).is_none());
        assert_eq!(cache.misses(), 1);
        cache.insert(key(1), artifact(1, false));
        let got = cache.get(&key(1)).unwrap();
        assert_eq!(got.meta.sketch_rows, 64);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.entries(), 1);
        assert!(cache.bytes() > 0);
    }

    #[test]
    fn byte_budget_evicts_lru_first() {
        let a1 = artifact(1, false);
        let a2 = artifact(2, false);
        let a3 = artifact(3, false);
        // budget fits exactly two step-1 artifacts
        let cache = PrecondCache::new(a1.bytes() + a2.bytes());
        cache.insert(key(1), a1);
        cache.insert(key(2), a2);
        assert_eq!(cache.entries(), 2);
        assert_eq!(cache.evictions(), 0);
        // touch key 1 so key 2 becomes the LRU victim
        assert!(cache.get(&key(1)).is_some());
        cache.insert(key(3), a3);
        assert_eq!(cache.evictions(), 1);
        assert!(cache.get(&key(2)).is_none(), "LRU entry should be gone");
        assert!(cache.get(&key(1)).is_some());
        assert!(cache.get(&key(3)).is_some());
        assert!(cache.bytes() <= cache.budget() || cache.entries() == 1);
    }

    #[test]
    fn oversize_artifact_still_caches_alone() {
        let big = artifact(1, true);
        let cache = PrecondCache::new(16); // absurdly small budget
        cache.insert(key(1), Arc::clone(&big));
        assert_eq!(cache.entries(), 1, "newest entry is never evicted");
        assert!(cache.get(&key(1)).is_some());
        // a second insert evicts the previous oversize one
        cache.insert(key(2), artifact(2, false));
        assert_eq!(cache.entries(), 1);
        assert!(cache.get(&key(1)).is_none());
    }

    #[test]
    fn replace_same_key_updates_bytes_not_entries() {
        let cache = PrecondCache::new(1 << 30);
        let plain = artifact(1, false);
        let full = artifact(1, true);
        cache.insert(key(1), plain);
        let b1 = cache.bytes();
        cache.insert(key(1), Arc::clone(&full));
        assert_eq!(cache.entries(), 1);
        assert_eq!(cache.bytes(), full.bytes());
        assert!(cache.bytes() > b1);
        assert_eq!(cache.inserts(), 2);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cache = PrecondCache::new(1 << 30);
        cache.insert(key(1), artifact(1, false));
        let mut k2 = key(1);
        k2.block_rows = 512;
        assert!(cache.get(&k2).is_none(), "block_rows is part of the key");
        let mut k3 = key(1);
        k3.sketch = SketchKind::Gaussian;
        assert!(cache.get(&k3).is_none(), "sketch kind is part of the key");
        let mut k4 = key(1);
        k4.backend = "pjrt".into();
        assert!(
            cache.get(&k4).is_none(),
            "backend kind is part of the key — executors must not alias"
        );
        let mut k5 = key(1);
        k5.repr = "csr".into();
        assert!(
            cache.get(&k5).is_none(),
            "representation is part of the key — dense and sparse artifacts must not alias"
        );
    }

    #[test]
    fn single_flight_elects_one_computer() {
        let cache = Arc::new(PrecondCache::new(1 << 30));
        // first caller claims
        let claim = match cache.lookup_or_claim(&key(1)) {
            Lookup::Claimed(c) => c,
            _ => panic!("empty cache must yield a claim"),
        };
        // second caller must NOT claim or count a second miss
        assert!(matches!(cache.lookup_or_claim(&key(1)), Lookup::Busy));
        assert_eq!(cache.misses(), 1);
        // a concurrent waiter blocks until publish, then finds the artifact
        let waiter = {
            let cache = Arc::clone(&cache);
            std::thread::spawn(move || {
                cache.wait_for(&key(1));
                matches!(cache.lookup_or_claim(&key(1)), Lookup::Found(_))
            })
        };
        claim.publish(artifact(1, false));
        assert!(waiter.join().unwrap(), "waiter must find the published artifact");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.entries(), 1);
    }

    #[test]
    fn abandoned_claim_unblocks_waiters() {
        let cache = PrecondCache::new(1 << 30);
        let claim = match cache.lookup_or_claim(&key(2)) {
            Lookup::Claimed(c) => c,
            _ => panic!("expected claim"),
        };
        drop(claim); // compute bailed (panic path): key must be released
        match cache.lookup_or_claim(&key(2)) {
            Lookup::Claimed(c) => c.publish(artifact(2, false)),
            _ => panic!("abandoned key must be re-claimable"),
        }
        assert!(cache.get(&key(2)).is_some());
    }

    #[test]
    fn snapshot_mentions_all_counters() {
        let cache = PrecondCache::new(1024);
        let s = cache.snapshot();
        for field in ["hits=", "misses=", "evictions=", "entries=", "bytes="] {
            assert!(s.contains(field), "{s}");
        }
    }
}
