//! Two-step preconditioning — the paper's core contribution.
//!
//! Step 1 (Algorithm 1): sketch `SA`, thin-QR it, keep `R`; `U = AR^{-1}` is
//! (O(sqrt d), O(1), 2)-conditioned, i.e. kappa(AR^{-1}) = O(1). We never
//! form U.
//!
//! Step 2 (Algorithm 2, step 2): apply the Randomized Hadamard Transform
//! `HD` to `[A | b]`, spreading row norms (Theorem 1) so *uniform*
//! mini-batch sampling has the variance bound of Lemma 9.

pub mod artifact;
pub mod cache;

pub use artifact::{ArtifactMeta, HdParts, PrecondArtifact};
pub use cache::{CacheOutcome, ComputeClaim, Lookup, PrecondCache, PrecondKey};

use crate::backend::Backend;
use crate::data::Dataset;
use crate::linalg::{qr, tri, CsrMat, Mat};
use crate::sketch::SketchKind;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

/// Output of step 1: the triangular preconditioner + timing for Table 2.
pub struct Precondition {
    /// Upper-triangular R from QR(SA): the preconditioner factor.
    pub r: Mat,
    /// Dense R^{-1}R^{-T} — shipped to the PJRT artifacts as `pinv`.
    pub pinv: Mat,
    /// Wall-clock cost of the sketch + QR (Table 2 measurements).
    pub sketch_secs: f64,
    pub qr_secs: f64,
    pub sketch_kind: SketchKind,
    pub sketch_rows: usize,
}

/// Step 1 of Algorithm 2/4/6: compute R such that AR^{-1} is
/// well-conditioned, via a sketch of A (we sketch A only; b is irrelevant
/// to conditioning).
///
/// The sketch streams row shards of `A` through the backend's executor
/// ([`Backend::sketch_apply`]): shards fold into per-worker partial
/// accumulators in parallel and merge deterministically, so nothing beyond
/// the `s x d` accumulators is allocated and the result matches the dense
/// single-pass product to 1e-12 (`tests/streaming_sketch.rs`). SRHT is the
/// documented dense-fallback exception. `block_rows = None` uses the
/// cache/thread heuristic.
pub fn precondition_with(
    backend: &Backend,
    a: &Mat,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
    block_rows: Option<usize>,
) -> Precondition {
    assert!(sketch_rows > a.cols, "sketch size must exceed d");
    let t = Timer::start();
    let sk = kind.build(sketch_rows, a.rows, rng);
    let sa = backend.sketch_apply(sk.as_ref(), a, block_rows);
    let sketch_secs = t.secs();
    let t = Timer::start();
    let r = qr::qr_r(&sa);
    let pinv = tri::pinv_dense(&r);
    let qr_secs = t.secs();
    Precondition {
        r,
        pinv,
        sketch_secs,
        qr_secs,
        sketch_kind: kind,
        sketch_rows,
    }
}

/// Backend-less convenience wrapper (benches, tests, one-off callers):
/// streams through a throwaway native executor with heuristic shard size.
pub fn precondition(
    a: &Mat,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
) -> Precondition {
    precondition_with(&Backend::native(), a, kind, sketch_rows, rng, None)
}

/// Step 1 on a CSR matrix — the input-sparsity-time setup. The sketch is
/// sampled from `rng` exactly as the dense path would (construction depends
/// only on `(s, n)`), then applied through the backend's nnz-sharded CSR
/// stream: O(nnz) for CountSketch, O(nnz log d) for the sparse embedding,
/// densify-per-shard for Gaussian and whole-matrix densify for SRHT
/// (documented fallbacks). The resulting `R` matches the dense path within
/// floating-point re-association (1e-10 acceptance in
/// `tests/sparse_parity.rs`).
pub fn precondition_csr_with(
    backend: &Backend,
    a: &CsrMat,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
    block_rows: Option<usize>,
) -> Precondition {
    assert!(sketch_rows > a.cols, "sketch size must exceed d");
    let t = Timer::start();
    let sk = kind.build(sketch_rows, a.rows, rng);
    let sa = backend.sketch_apply_csr(sk.as_ref(), a, block_rows);
    let sketch_secs = t.secs();
    let t = Timer::start();
    let r = qr::qr_r(&sa);
    let pinv = tri::pinv_dense(&r);
    let qr_secs = t.secs();
    Precondition {
        r,
        pinv,
        sketch_secs,
        qr_secs,
        sketch_kind: kind,
        sketch_rows,
    }
}

/// Representation-aware step 1 for a [`Dataset`]: routes the CSR pipeline
/// when the dataset is sparse, the dense streamed pipeline otherwise. The
/// rng consumption is identical either way (the sketch is sampled before
/// representation matters), so dense and sparse artifacts for the same
/// seed use the *same* sketch operator — the parity tests rely on this.
pub fn precondition_ds_with(
    backend: &Backend,
    ds: &Dataset,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
    block_rows: Option<usize>,
) -> Precondition {
    match &ds.csr {
        Some(c) => precondition_csr_with(backend, c, kind, sketch_rows, rng, block_rows),
        None => precondition_with(backend, &ds.a, kind, sketch_rows, rng, block_rows),
    }
}

/// Step 2: the Randomized Hadamard Transform applied to [A | b] packed as an
/// n x (d+1) matrix. Pads n to a power of two. Returns (HDA, HDb, n_pad).
///
/// Padding note: FWHT needs 2^k rows; padding appends zero rows, which are
/// valid "samples" of the transformed system (they contribute zero
/// gradient in expectation scaled consistently) — we keep the *padded* row
/// count as the sampling universe exactly like zero-padding the dataset.
pub struct HdTransformed {
    pub hda: Mat,
    pub hdb: Vec<f64>,
    /// padded row count (sampling universe size)
    pub n_pad: usize,
    pub secs: f64,
}

/// Backend-routed HD transform. Memory discipline: the padded [A | b] FWHT
/// buffer is built in ONE allocation (`Mat::hstack_col_padded` — the dense
/// [A | b] is never materialized separately, and no pad-time clone exists),
/// transformed in place on the native route (`Backend::hd_transform_mut`),
/// and split in place afterwards (`Mat::into_split_last_col`). Peak extra
/// memory beyond the caller's `A` is the single padded buffer,
/// `n_pad x (d+1)` — versus the seed's hstack + pad + split chain which
/// held ~3 copies of A at once.
pub fn hd_transform_with(
    backend: &Backend,
    a: &Mat,
    b: &[f64],
    rng: &mut Rng,
) -> HdTransformed {
    assert_eq!(a.rows, b.len());
    let t = Timer::start();
    let n_pad = a.rows.next_power_of_two();
    let mut padded = a.hstack_col_padded(b, n_pad);
    let signs = rng.signs(n_pad);
    backend.hd_transform_mut(&mut padded, &signs);
    let (hda, hdb) = padded.into_split_last_col();
    HdTransformed {
        hda,
        hdb,
        n_pad,
        secs: t.secs(),
    }
}

/// Backend-less convenience wrapper (tests, one-off callers).
pub fn hd_transform(a: &Mat, b: &[f64], rng: &mut Rng) -> HdTransformed {
    hd_transform_with(&Backend::native(), a, b, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::eigen;

    fn syn(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        (a, b)
    }

    #[test]
    fn preconditioner_gives_o1_condition_number() {
        let (a, _) = syn(2048, 12, 1);
        let mut rng = Rng::new(7);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::Srht,
            SketchKind::Gaussian,
            SketchKind::SparseEmbed,
        ] {
            let p = precondition(&a, kind, 480, &mut rng);
            let g = blas::gram(&a);
            let kappa = eigen::cond_preconditioned(&g, &p.r);
            assert!(
                kappa < 3.0,
                "{}: kappa(AR^-1) = {kappa}, expected O(1)",
                kind.name()
            );
        }
    }

    #[test]
    fn preconditioner_beats_raw_condition_number() {
        // ill-conditioned A: scale columns wildly
        let (mut a, _) = syn(1024, 8, 2);
        for i in 0..a.rows {
            for j in 0..a.cols {
                *a.at_mut(i, j) *= 10f64.powi(j as i32);
            }
        }
        let raw_kappa = eigen::cond(&a);
        assert!(raw_kappa > 1e5);
        let mut rng = Rng::new(3);
        let p = precondition(&a, SketchKind::CountSketch, 400, &mut rng);
        let g = blas::gram(&a);
        let kappa = eigen::cond_preconditioned(&g, &p.r);
        assert!(kappa < 5.0, "kappa {kappa}");
    }

    #[test]
    fn hd_transform_preserves_objective() {
        // ||HDAx - HDb|| == ||Ax - b|| for any x (H, D orthogonal) modulo
        // zero padding (which adds zero rows to both sides).
        let (a, b) = syn(500, 6, 4); // pads to 512
        let mut rng = Rng::new(5);
        let hd = hd_transform(&a, &b, &mut rng);
        assert_eq!(hd.n_pad, 512);
        let x = rng.gaussians(6);
        let f_orig = blas::residual_sq(&a, &b, &x);
        let f_hd = blas::residual_sq(&hd.hda, &hd.hdb, &x);
        assert!(
            (f_orig - f_hd).abs() < 1e-8 * (1.0 + f_orig),
            "{f_orig} vs {f_hd}"
        );
    }

    #[test]
    fn hd_transform_flattens_leverage() {
        // row norms of HDA are far more uniform than those of a spiky A
        let mut a = Mat::zeros(256, 4);
        for j in 0..4 {
            *a.at_mut(j, j) = 10.0;
        }
        let b = vec![0.0; 256];
        let mut rng = Rng::new(6);
        let hd = hd_transform(&a, &b, &mut rng);
        let norms: Vec<f64> = (0..hd.hda.rows)
            .map(|i| blas::nrm2(hd.hda.row(i)))
            .collect();
        let max = norms.iter().cloned().fold(0.0, f64::max);
        let mean = norms.iter().sum::<f64>() / norms.len() as f64;
        assert!(
            max / mean < 6.0,
            "row norms still spiky: max {max}, mean {mean}"
        );
    }

    #[test]
    fn streamed_precondition_matches_dense_r() {
        // R from the block-streamed parallel sketch must equal R from the
        // dense single-pass apply to 1e-12, for every construction
        let (a, _) = syn(1024, 10, 9);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::Gaussian,
            SketchKind::SparseEmbed,
            SketchKind::Srht,
        ] {
            // dense reference, sketch sampled from an identical rng stream
            let mut r1 = Rng::new(42);
            let sk = kind.build(300, a.rows, &mut r1);
            let dense_r = qr::qr_r(&sk.apply(&a));
            let mut r2 = Rng::new(42);
            let be = Backend::native_with(4, None);
            let p = precondition_with(&be, &a, kind, 300, &mut r2, Some(128));
            assert!(
                p.r.max_abs_diff(&dense_r) < 1e-12,
                "{}: streamed R != dense R",
                kind.name()
            );
        }
    }

    #[test]
    fn csr_precondition_matches_dense_within_reassociation() {
        let mut rng = Rng::new(21);
        let dense = Mat::from_fn(600, 8, |_, _| {
            if rng.uniform() < 0.25 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let be = Backend::native_with(4, None);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::SparseEmbed,
            SketchKind::Gaussian,
            SketchKind::Srht,
        ] {
            let mut r1 = Rng::new(77);
            let p_dense = precondition_with(&be, &dense, kind, 160, &mut r1, Some(64));
            let mut r2 = Rng::new(77);
            let p_csr = precondition_csr_with(&be, &csr, kind, 160, &mut r2, Some(64));
            assert!(
                p_csr.r.max_abs_diff(&p_dense.r) < 1e-10,
                "{}: csr R != dense R",
                kind.name()
            );
            // and the rng streams end in the same state (same sketch draws)
            assert_eq!(r1.next_u64(), r2.next_u64(), "{}", kind.name());
        }
    }

    #[test]
    fn ds_precondition_routes_by_representation() {
        let mut rng = Rng::new(23);
        let dense = Mat::from_fn(300, 5, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(300);
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let ds_sparse = crate::data::Dataset::from_csr("sp", csr, b.clone(), None);
        let ds_dense = crate::data::Dataset {
            name: "dn".into(),
            a: dense,
            csr: None,
            b,
            x_star_planted: None,
        };
        let be = Backend::native();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let ps = precondition_ds_with(&be, &ds_sparse, SketchKind::CountSketch, 80, &mut r1, None);
        let pd = precondition_ds_with(&be, &ds_dense, SketchKind::CountSketch, 80, &mut r2, None);
        assert!(ps.r.max_abs_diff(&pd.r) < 1e-10);
    }

    #[test]
    fn hd_with_backend_matches_wrapper() {
        let (a, b) = syn(300, 4, 11); // pads to 512
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let via_wrapper = hd_transform(&a, &b, &mut r1);
        let via_backend = hd_transform_with(&Backend::native(), &a, &b, &mut r2);
        assert_eq!(via_wrapper.n_pad, via_backend.n_pad);
        assert_eq!(via_wrapper.hdb, via_backend.hdb);
        assert!(via_wrapper.hda.max_abs_diff(&via_backend.hda) == 0.0);
    }

    #[test]
    fn timings_are_recorded() {
        let (a, b) = syn(1024, 8, 7);
        let mut rng = Rng::new(8);
        let p = precondition(&a, SketchKind::CountSketch, 200, &mut rng);
        assert!(p.sketch_secs >= 0.0 && p.qr_secs >= 0.0);
        let hd = hd_transform(&a, &b, &mut rng);
        assert!(hd.secs >= 0.0);
    }
}
