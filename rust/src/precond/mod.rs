//! Two-step preconditioning — the paper's core contribution.
//!
//! Step 1 (Algorithm 1): sketch `SA`, thin-QR it, keep `R`; `U = AR^{-1}` is
//! (O(sqrt d), O(1), 2)-conditioned, i.e. kappa(AR^{-1}) = O(1). We never
//! form U.
//!
//! Step 2 (Algorithm 2, step 2): apply the Randomized Hadamard Transform
//! `HD` to `[A | b]`, spreading row norms (Theorem 1) so *uniform*
//! mini-batch sampling has the variance bound of Lemma 9.

pub mod artifact;
pub mod cache;

pub use artifact::{ArtifactMeta, HdParts, HdView, PrecondArtifact};
pub use cache::{CacheOutcome, ComputeClaim, Lookup, PrecondCache, PrecondKey};

use crate::backend::Backend;
use crate::data::{Dataset, OnDiskDesign};
use crate::linalg::{qr, tri, CsrMat, Mat};
use crate::sketch::SketchKind;
use crate::util::mem::{MemBudget, MemCharge, MemError};
use crate::util::rng::Rng;
use crate::util::stats::Timer;
use std::sync::Arc;

/// Output of step 1: the triangular preconditioner + timing for Table 2.
pub struct Precondition {
    /// Upper-triangular R from QR(SA): the preconditioner factor.
    pub r: Mat,
    /// Dense R^{-1}R^{-T} — shipped to the PJRT artifacts as `pinv`.
    pub pinv: Mat,
    /// Wall-clock cost of the sketch + QR (Table 2 measurements).
    pub sketch_secs: f64,
    /// Wall-clock cost of the QR factorization alone.
    pub qr_secs: f64,
    /// Sketch construction used.
    pub sketch_kind: SketchKind,
    /// Sketch rows s.
    pub sketch_rows: usize,
}

/// Step 1 of Algorithm 2/4/6: compute R such that AR^{-1} is
/// well-conditioned, via a sketch of A (we sketch A only; b is irrelevant
/// to conditioning).
///
/// The sketch streams row shards of `A` through the backend's executor
/// ([`Backend::sketch_apply`]): shards fold into per-worker partial
/// accumulators in parallel and merge deterministically, so nothing beyond
/// the `s x d` accumulators is allocated and the result matches the dense
/// single-pass product to 1e-12 (`tests/streaming_sketch.rs`). SRHT is the
/// documented dense-fallback exception. `block_rows = None` uses the
/// cache/thread heuristic.
pub fn precondition_with(
    backend: &Backend,
    a: &Mat,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
    block_rows: Option<usize>,
) -> Precondition {
    assert!(sketch_rows > a.cols, "sketch size must exceed d");
    let t = Timer::start();
    let sk = kind.build(sketch_rows, a.rows, rng);
    let sa = backend.sketch_apply(sk.as_ref(), a, block_rows);
    let sketch_secs = t.secs();
    let t = Timer::start();
    let r = qr::qr_r(&sa);
    let pinv = tri::pinv_dense(&r);
    let qr_secs = t.secs();
    Precondition {
        r,
        pinv,
        sketch_secs,
        qr_secs,
        sketch_kind: kind,
        sketch_rows,
    }
}

/// Backend-less convenience wrapper (benches, tests, one-off callers):
/// streams through a throwaway native executor with heuristic shard size.
pub fn precondition(
    a: &Mat,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
) -> Precondition {
    precondition_with(&Backend::native(), a, kind, sketch_rows, rng, None)
}

/// Step 1 on a CSR matrix — the input-sparsity-time setup. The sketch is
/// sampled from `rng` exactly as the dense path would (construction depends
/// only on `(s, n)`), then applied through the backend's nnz-sharded CSR
/// stream: O(nnz) for CountSketch, O(nnz log d) for the sparse embedding,
/// densify-per-shard for Gaussian and whole-matrix densify for SRHT
/// (documented fallbacks). The resulting `R` matches the dense path within
/// floating-point re-association (1e-10 acceptance in
/// `tests/sparse_parity.rs`).
pub fn precondition_csr_with(
    backend: &Backend,
    a: &CsrMat,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
    block_rows: Option<usize>,
) -> Precondition {
    assert!(sketch_rows > a.cols, "sketch size must exceed d");
    let t = Timer::start();
    let sk = kind.build(sketch_rows, a.rows, rng);
    let sa = backend.sketch_apply_csr(sk.as_ref(), a, block_rows);
    let sketch_secs = t.secs();
    let t = Timer::start();
    let r = qr::qr_r(&sa);
    let pinv = tri::pinv_dense(&r);
    let qr_secs = t.secs();
    Precondition {
        r,
        pinv,
        sketch_secs,
        qr_secs,
        sketch_kind: kind,
        sketch_rows,
    }
}

/// Step 1 on a disk-backed design — the out-of-core setup path. The sketch
/// is sampled from `rng` exactly as the resident paths would (construction
/// depends only on `(s, n)`), then applied through
/// [`Backend::sketch_apply_ondisk`]: shard-cache scratch blocks fold on the
/// same partition / merge order as the matching in-memory stream, so `R` is
/// bitwise identical to preconditioning a resident twin of the file.
/// Fallible like every disk access — a shard I/O error or refused cache
/// charge propagates instead of panicking.
pub fn precondition_ondisk_with(
    backend: &Backend,
    od: &OnDiskDesign,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
    block_rows: Option<usize>,
) -> anyhow::Result<Precondition> {
    assert!(sketch_rows > od.cols(), "sketch size must exceed d");
    let t = Timer::start();
    let sk = kind.build(sketch_rows, od.rows(), rng);
    let sa = backend.sketch_apply_ondisk(sk.as_ref(), od, block_rows)?;
    let sketch_secs = t.secs();
    let t = Timer::start();
    let r = qr::qr_r(&sa);
    let pinv = tri::pinv_dense(&r);
    let qr_secs = t.secs();
    Ok(Precondition {
        r,
        pinv,
        sketch_secs,
        qr_secs,
        sketch_kind: kind,
        sketch_rows,
    })
}

/// Representation-aware step 1 for a [`Dataset`]: routes the CSR pipeline
/// when the dataset is sparse, the dense streamed pipeline otherwise. The
/// rng consumption is identical either way (the sketch is sampled before
/// representation matters), so dense and sparse artifacts for the same
/// seed use the *same* sketch operator — the parity tests rely on this.
/// On-disk datasets are rejected: their shard reads are fallible, so they
/// route through [`precondition_ds_budgeted`] (which every production
/// caller already uses).
pub fn precondition_ds_with(
    backend: &Backend,
    ds: &Dataset,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
    block_rows: Option<usize>,
) -> Precondition {
    assert!(
        ds.on_disk().is_none(),
        "on-disk dataset: precondition must route through the fallible \
         precondition_ds_budgeted entry"
    );
    match ds.csr() {
        Some(c) => precondition_csr_with(backend, c, kind, sketch_rows, rng, block_rows),
        None => precondition_with(
            backend,
            ds.dense_if_ready().expect("dense dataset"),
            kind,
            sketch_rows,
            rng,
            block_rows,
        ),
    }
}

/// [`precondition_ds_with`] with the whole-matrix-densifying sketch (SRHT —
/// its Hadamard butterfly needs every row at once, DESIGN.md §10) routed
/// through the memory budget: the transient dense view is acquired as a
/// drop-after-use capability ([`Dataset::dense_scoped`]) — charged, counted
/// as a densify event, released right after the sketch — instead of the
/// untracked `to_dense()` inside the sketch-layer fallback. Numerically
/// identical (both paths reduce to `sk.apply(dense)` on the same matrix);
/// over budget it fails with the structured error. Streaming kinds
/// (CountSketch, SparseEmbed, per-shard Gaussian) charge nothing and take
/// the plain O(nnz) route. Every production caller routes through here:
/// artifact construction *and* IHS's in-loop re-sketch
/// (`SolveSession::fresh_precond`) — `StepRule::step` is fallible, so an
/// over-budget mid-solve re-sketch propagates as the job's structured
/// error too. The infallible [`precondition_ds_with`] remains only as the
/// uncharged building block (tests, benches, the budgeted wrapper itself).
pub fn precondition_ds_budgeted(
    backend: &Backend,
    ds: &Dataset,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
    block_rows: Option<usize>,
    budget: &Arc<MemBudget>,
) -> anyhow::Result<Precondition> {
    if let Some(od) = ds.on_disk() {
        // shard-cache streamed fold; SRHT's whole-matrix fallback runs as a
        // charged dense_scoped materialization inside the ondisk fold
        return precondition_ondisk_with(backend, od, kind, sketch_rows, rng, block_rows);
    }
    if kind == SketchKind::Srht && ds.is_sparse() {
        let stage = format!("srht_sketch[{}]", ds.name);
        let view = ds.dense_scoped(budget, &stage)?;
        return Ok(precondition_with(
            backend,
            &view,
            kind,
            sketch_rows,
            rng,
            block_rows,
        ));
    }
    Ok(precondition_ds_with(
        backend, ds, kind, sketch_rows, rng, block_rows,
    ))
}

/// Step 2: the Randomized Hadamard Transform applied to [A | b] packed as an
/// n x (d+1) matrix. Pads n to a power of two. Returns (HDA, HDb, n_pad).
///
/// Padding note: FWHT needs 2^k rows; padding appends zero rows, which are
/// valid "samples" of the transformed system (they contribute zero
/// gradient in expectation scaled consistently) — we keep the *padded* row
/// count as the sampling universe exactly like zero-padding the dataset.
pub struct HdTransformed {
    /// The transformed (padded) design HDA.
    pub hda: Mat,
    /// The transformed (padded) response HDb.
    pub hdb: Vec<f64>,
    /// padded row count (sampling universe size)
    pub n_pad: usize,
    /// Wall-clock cost of the transform.
    pub secs: f64,
    /// The budget charge covering the transformed buffer — held for as long
    /// as the HD data is resident (it rides into `HdParts`, so a cached
    /// artifact keeps its HD bytes accounted until eviction). `None` on the
    /// uncharged `hd_transform_with` convenience entry.
    pub mem: Option<Arc<MemCharge>>,
}

/// Backend-routed HD transform. Memory discipline: the padded [A | b] FWHT
/// buffer is built in ONE allocation (`Mat::hstack_col_padded` — the dense
/// [A | b] is never materialized separately, and no pad-time clone exists),
/// transformed in place on the native route (`Backend::hd_transform_mut`),
/// and split in place afterwards (`Mat::into_split_last_col`). Peak extra
/// memory beyond the caller's `A` is the single padded buffer,
/// `n_pad x (d+1)` — versus the seed's hstack + pad + split chain which
/// held ~3 copies of A at once.
pub fn hd_transform_with(
    backend: &Backend,
    a: &Mat,
    b: &[f64],
    rng: &mut Rng,
) -> HdTransformed {
    assert_eq!(a.rows, b.len());
    let t = Timer::start();
    let n_pad = a.rows.next_power_of_two();
    let mut padded = a.hstack_col_padded(b, n_pad);
    let signs = rng.signs(n_pad);
    backend.hd_transform_mut(&mut padded, &signs);
    let (hda, hdb) = padded.into_split_last_col();
    HdTransformed {
        hda,
        hdb,
        n_pad,
        secs: t.secs(),
        mem: None,
    }
}

/// Bytes of the padded `[A | b]` FWHT buffer for an `n x d` dataset — the
/// ONE formula shared by the actual charge ([`hd_transform_ds_with`]) and
/// the coordinator's admission estimate, so the gate and the capability can
/// never drift apart.
pub fn hd_buffer_bytes(n: usize, d: usize) -> usize {
    n.next_power_of_two() * (d + 1) * std::mem::size_of::<f64>()
}

/// Representation-aware, budget-accounted HD transform for a [`Dataset`]
/// (the serve-path entry every artifact construction routes through). The
/// padded `[A | b]` buffer — the only dense object step 2 ever needs — is
/// charged against `budget` *before* allocating and built in one
/// allocation either from the dense payload (bit-identical to
/// [`hd_transform_with`]) or **straight from CSR** — a sparse dataset's HD
/// step never materializes a standalone dense mirror. Over budget it
/// returns the structured [`MemError`] (a job error, never an OOM); on a
/// CSR dataset the materialization is counted as one densify event tagged
/// with `stage`. On-disk datasets stream shards into the charged padded
/// buffer (bitwise the bits a resident twin would produce): the chunked
/// flavor counts one densify event exactly like resident CSR, while
/// mmapdense does not (its payload is already dense, merely non-resident)
/// — and a shard I/O error propagates as the job's structured error.
pub fn hd_transform_ds_with(
    backend: &Backend,
    ds: &Dataset,
    rng: &mut Rng,
    budget: &Arc<MemBudget>,
    stage: &str,
) -> anyhow::Result<HdTransformed> {
    assert_eq!(ds.n(), ds.b.len());
    let t = Timer::start();
    let n_pad = ds.n().next_power_of_two();
    let bytes = hd_buffer_bytes(ds.n(), ds.d());
    let charge = budget.try_charge(bytes, stage)?;
    let mut padded = match (ds.on_disk(), ds.csr()) {
        (Some(od), _) => {
            if od.sparse_arith() {
                budget.note_densify(stage, bytes);
            }
            od.hstack_col_padded(&ds.b, n_pad)?
        }
        (None, Some(c)) => {
            budget.note_densify(stage, bytes);
            c.hstack_col_padded(&ds.b, n_pad)
        }
        (None, None) => ds
            .dense_if_ready()
            .expect("dense dataset")
            .hstack_col_padded(&ds.b, n_pad),
    };
    let signs = rng.signs(n_pad);
    backend.hd_transform_mut(&mut padded, &signs);
    let (hda, hdb) = padded.into_split_last_col();
    Ok(HdTransformed {
        hda,
        hdb,
        n_pad,
        secs: t.secs(),
        mem: Some(Arc::new(charge)),
    })
}

/// Backend-less convenience wrapper (tests, one-off callers).
pub fn hd_transform(a: &Mat, b: &[f64], rng: &mut Rng) -> HdTransformed {
    hd_transform_with(&Backend::native(), a, b, rng)
}

/// Request-level step-2 representation policy — the `--step2` knob a
/// [`crate::coordinator::JobRequest`] carries. [`resolve_step2`] turns it
/// into a concrete [`Step2Mode`] (+ the report string) per job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Step2Policy {
    /// Representation-pinned (the default and the paper path): dense
    /// datasets materialize `HD[A|b]`, sparse datasets hold it implicitly —
    /// the CSR pipeline stays zero-densify, which the CI acceptance gates
    /// pin.
    #[default]
    Repr,
    /// Force the materialized transform (budget-charged; on CSR it is a
    /// counted densify event).
    Dense,
    /// Force the implicit transform (meaningful on CSR datasets; dense
    /// datasets have no sparse payload to gather from and stay
    /// materialized).
    Implicit,
    /// nnz-aware cost model picks dense vs implicit per job; never picks a
    /// dense buffer the [`MemBudget`] cannot charge.
    Auto,
}

impl Step2Policy {
    /// Parse the request string (`"" | "repr" | "dense" | "implicit" |
    /// "auto"`); `None` on anything else.
    pub fn parse(s: &str) -> Option<Step2Policy> {
        match s {
            "" | "repr" => Some(Step2Policy::Repr),
            "dense" => Some(Step2Policy::Dense),
            "implicit" => Some(Step2Policy::Implicit),
            "auto" => Some(Step2Policy::Auto),
            _ => None,
        }
    }

    /// Canonical name (CLI help, report fields).
    pub fn name(self) -> &'static str {
        match self {
            Step2Policy::Repr => "repr",
            Step2Policy::Dense => "dense",
            Step2Policy::Implicit => "implicit",
            Step2Policy::Auto => "auto",
        }
    }
}

/// The resolved step-2 representation an artifact is built with.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Step2Mode {
    /// Match the data representation (legacy behavior: dense datasets
    /// materialize, CSR stays implicit).
    #[default]
    Repr,
    /// Materialize `HD[A|b]` even on CSR (charged, counted densify).
    Dense,
    /// Hold step 2 implicitly (CSR datasets; no-op pin on dense data).
    Implicit,
}

/// Resolve a [`Step2Policy`] for one job into the [`Step2Mode`] the
/// artifact layer builds with, plus the report string
/// (`dense | implicit | auto→dense | auto→implicit`).
///
/// The `Auto` cost model compares, in units of f64 touches:
///
/// * dense: `n_pad·(d+1)·(log2(n_pad)+2)` to materialize + FWHT the padded
///   buffer once, plus `total_rows·(d+1)` for the per-iteration row copies;
/// * implicit: `total_rows·(nnz + n)` — every sampled row costs one
///   coefficient pass over the sign vector plus one scatter of the CSR
///   payload (the blockwise gather amortizes the *memory traffic*, not the
///   flops);
///
/// where `total_rows = max_iters × batch_size` is the job's expected
/// sampled-row volume. Dense wins only when it is both cheaper *and*
/// chargeable right now (`budget.would_fit` on [`hd_buffer_bytes`]) — the
/// auto policy never resolves to a buffer the budget can't hold, so the
/// worst case under memory pressure is the implicit path, never a
/// structured over-budget error.
pub fn resolve_step2(
    policy: Step2Policy,
    ds: &Dataset,
    total_rows: usize,
    budget: &Arc<MemBudget>,
) -> (Step2Mode, String) {
    match policy {
        Step2Policy::Repr => {
            // sparse_arith, not is_sparse: a chunked on-disk dataset pins
            // implicit exactly like resident CSR, mmapdense pins dense
            let eff = if ds.sparse_arith() { "implicit" } else { "dense" };
            (Step2Mode::Repr, eff.into())
        }
        Step2Policy::Dense => (Step2Mode::Dense, "dense".into()),
        Step2Policy::Implicit => (Step2Mode::Implicit, "implicit".into()),
        Step2Policy::Auto => {
            if !ds.sparse_arith() {
                // dense data: the materialized form is both the bit-exact
                // reference and the cheaper one (rows are plain copies)
                return (Step2Mode::Repr, "auto→dense".into());
            }
            let (n, d) = (ds.n(), ds.d());
            let n_pad = n.next_power_of_two().max(2);
            let rows = total_rows.max(1) as f64;
            let dense_cost = (n_pad * (d + 1)) as f64 * ((n_pad as f64).log2() + 2.0)
                + rows * (d + 1) as f64;
            let implicit_cost = rows * (ds.nnz() + n) as f64;
            if dense_cost < implicit_cost && budget.would_fit(hd_buffer_bytes(n, d)) {
                (Step2Mode::Dense, "auto→dense".into())
            } else {
                (Step2Mode::Repr, "auto→implicit".into())
            }
        }
    }
}

/// Step 2 in **implicit** form — the sparsity-preserving Randomized
/// Hadamard Transform for CSR datasets.
///
/// The dense step 2 materializes the full `n_pad x (d+1)` buffer `HD[A|b]`
/// because the mini-batch solvers sample rows of it. But they only ever
/// *sample*: a batch touches `r` rows per iteration, never the whole
/// transform. Since the orthonormal Hadamard matrix has the closed form
/// `H[i][j] = (-1)^popcount(i & j) / sqrt(n_pad)`, any single transformed
/// row is a signed sum over the original rows:
///
/// ```text
/// (HD[A|b])_i = (1/sqrt(n_pad)) * sum_{j<n} signs[j] * (-1)^popcount(i&j) * [A_j | b_j]
/// ```
///
/// (rows `j >= n` are zero padding and drop out). On CSR that sum is an
/// O(nnz + n) scatter per sampled row — input-sparsity time per batch, and
/// the dense buffer is **never** built: a sparse dataset's step 2 stores
/// only the Rademacher sign vector. The dense path stays the bit-exact
/// golden reference ([`hd_transform_ds_with`]); this path matches it to
/// floating-point re-association (1e-10 acceptance, same discipline as the
/// CSR sketch fold).
#[derive(Clone, Debug)]
pub struct ImplicitHd {
    /// The Rademacher sign vector of D (length `n_pad`), drawn from the
    /// same rng stream position as the dense path's sign draw — dense and
    /// implicit artifacts for one key share the diagonal.
    pub signs: Vec<f64>,
    /// Padded row universe (`n.next_power_of_two()`): the sampling
    /// universe, exactly as for the dense transform.
    pub n_pad: usize,
    /// Wall-clock cost of constructing the implicit transform (sign draw).
    pub secs: f64,
}

/// Build the implicit step-2 for `ds`: draws `signs(n_pad)` from `rng` —
/// the *same* consumption as [`hd_transform_ds_with`], so a keyed rng
/// stream produces the identical diagonal whether step 2 is materialized
/// or implicit. Charges nothing: there is no buffer.
pub fn hd_implicit_ds(ds: &Dataset, rng: &mut Rng) -> ImplicitHd {
    let t = Timer::start();
    let n_pad = ds.n().next_power_of_two();
    let signs = rng.signs(n_pad);
    ImplicitHd {
        signs,
        n_pad,
        secs: t.secs(),
    }
}

/// Default sampled-row tile for the blockwise implicit gather: bounds the
/// output panel (`GATHER_BLOCK x (d+1)` of f64) touched while one CSR source
/// row is cache-hot. 128 rows x 101 cols ≈ 100 KiB — inside L2 on every
/// target arch, large enough to amortize the CSR traversal ~128x. Callers
/// with a natural batch size (the step rules) pass it explicitly through
/// [`HdView::gather_blocked`](artifact::HdView::gather_blocked).
pub const GATHER_BLOCK: usize = 128;

impl ImplicitHd {
    /// Materialize the sampled rows `idx` of `HD[A|b]` straight from CSR,
    /// returning the `idx.len() x d` design rows and the matching
    /// transformed responses. This is the ONLY dense object the implicit
    /// step 2 ever produces — a batch-sized gather, identical in shape to
    /// what the dense path's `gather_rows` hands the executors.
    ///
    /// Blockwise since PR 9: source rows iterate *outer*, sampled rows
    /// *inner*, so each CSR byte is read once per batch instead of once per
    /// sampled row (O(nnz + r·n) per batch vs the reference's O(r·(nnz+n))
    /// memory traffic). Bit-identical to [`Self::gather_rows_csr_ref`]: per
    /// output cell the same coefficients accumulate in the same ascending-j
    /// order with the same plain mul+add arithmetic (the
    /// [`crate::simd::hd_scatter_row`] kernel contract).
    pub fn gather_rows_csr(&self, a: &CsrMat, b: &[f64], idx: &[usize]) -> (Mat, Vec<f64>) {
        self.gather_rows_csr_blocked(a, b, idx, 0)
    }

    /// [`Self::gather_rows_csr`] with an explicit sampled-row tile size
    /// (`block == 0` means [`GATHER_BLOCK`]). The step rules pass their
    /// mini-batch size so one solver batch is one tile.
    pub fn gather_rows_csr_blocked(
        &self,
        a: &CsrMat,
        b: &[f64],
        idx: &[usize],
        block: usize,
    ) -> (Mat, Vec<f64>) {
        assert_eq!(a.rows, b.len());
        assert!(a.rows <= self.n_pad);
        for &i in idx {
            assert!(
                i < self.n_pad,
                "sample index {i} outside the padded universe {}",
                self.n_pad
            );
        }
        let block = if block == 0 { GATHER_BLOCK } else { block };
        let inv = 1.0 / (self.n_pad as f64).sqrt();
        let ld = a.cols;
        let mut out = Mat::zeros(idx.len(), ld);
        let mut outb = vec![0.0; idx.len()];
        let mut coeffs = vec![0.0; block.min(idx.len().max(1))];
        let mut lo = 0;
        while lo < idx.len() {
            let hi = (lo + block).min(idx.len());
            let tile = &idx[lo..hi];
            let cs = &mut coeffs[..tile.len()];
            let out_tile = &mut out.data[lo * ld..hi * ld];
            let outb_tile = &mut outb[lo..hi];
            for j in 0..a.rows {
                // sign panel: per-(i,j) Rademacher·parity coefficient for
                // every sampled row in the tile, computed up front so the
                // scatter kernel only streams
                for (k, &i) in tile.iter().enumerate() {
                    // (-1)^popcount(i & j): +1 on even parity, -1 on odd
                    let parity = if (i & j).count_ones() & 1 == 1 { -1.0 } else { 1.0 };
                    cs[k] = self.signs[j] * parity * inv;
                }
                let (cols, vals) = a.row(j);
                crate::simd::hd_scatter_row(cols, vals, b[j], cs, out_tile, ld, outb_tile);
            }
            lo = hi;
        }
        (out, outb)
    }

    /// [`Self::gather_rows_csr_blocked`] for a chunked on-disk design: the
    /// CSR payload streams shard by shard through the block cache in ONE
    /// ascending-row pass (`OnDiskDesign::stream_csr_rows`), scattering each
    /// source row into every sampled-row tile before moving on. Tiles cover
    /// disjoint output panels, so per output cell the coefficients still
    /// accumulate in the same ascending-`j` order with the same
    /// [`crate::simd::hd_scatter_row`] arithmetic — bitwise identical to the
    /// resident blockwise gather on a CSR twin of the file, at one file pass
    /// per batch instead of one per tile. Fallible like every disk access.
    pub fn gather_rows_ondisk_blocked(
        &self,
        od: &OnDiskDesign,
        idx: &[usize],
        block: usize,
    ) -> anyhow::Result<(Mat, Vec<f64>)> {
        assert!(
            od.sparse_arith(),
            "implicit on-disk gather requires the chunked CSR flavor"
        );
        let b = od.b();
        assert_eq!(od.rows(), b.len());
        assert!(od.rows() <= self.n_pad);
        for &i in idx {
            assert!(
                i < self.n_pad,
                "sample index {i} outside the padded universe {}",
                self.n_pad
            );
        }
        let block = if block == 0 { GATHER_BLOCK } else { block };
        let inv = 1.0 / (self.n_pad as f64).sqrt();
        let ld = od.cols();
        let mut out = Mat::zeros(idx.len(), ld);
        let mut outb = vec![0.0; idx.len()];
        let mut coeffs = vec![0.0; block.min(idx.len().max(1))];
        od.stream_csr_rows(&mut |j, cols, vals| {
            let mut lo = 0;
            while lo < idx.len() {
                let hi = (lo + block).min(idx.len());
                let tile = &idx[lo..hi];
                let cs = &mut coeffs[..tile.len()];
                for (k, &i) in tile.iter().enumerate() {
                    // (-1)^popcount(i & j): +1 on even parity, -1 on odd
                    let parity = if (i & j).count_ones() & 1 == 1 { -1.0 } else { 1.0 };
                    cs[k] = self.signs[j] * parity * inv;
                }
                let out_tile = &mut out.data[lo * ld..hi * ld];
                let outb_tile = &mut outb[lo..hi];
                crate::simd::hd_scatter_row(cols, vals, b[j], cs, out_tile, ld, outb_tile);
                lo = hi;
            }
        })?;
        Ok((out, outb))
    }

    /// The original per-sampled-row gather (sampled rows outer, one full
    /// CSR pass each): kept as the bit-exact oracle for the blockwise path
    /// (`tests/implicit_gather.rs`) and the baseline leg of `BENCH_gather`.
    pub fn gather_rows_csr_ref(&self, a: &CsrMat, b: &[f64], idx: &[usize]) -> (Mat, Vec<f64>) {
        assert_eq!(a.rows, b.len());
        assert!(a.rows <= self.n_pad);
        let inv = 1.0 / (self.n_pad as f64).sqrt();
        let mut out = Mat::zeros(idx.len(), a.cols);
        let mut outb = vec![0.0; idx.len()];
        for (k, &i) in idx.iter().enumerate() {
            assert!(
                i < self.n_pad,
                "sample index {i} outside the padded universe {}",
                self.n_pad
            );
            let row = out.row_mut(k);
            let mut acc_b = 0.0;
            for j in 0..a.rows {
                // (-1)^popcount(i & j): +1 on even parity, -1 on odd
                let parity = if (i & j).count_ones() & 1 == 1 { -1.0 } else { 1.0 };
                let c = self.signs[j] * parity * inv;
                a.row_axpy(j, c, row);
                acc_b += c * b[j];
            }
            outb[k] = acc_b;
        }
        (out, outb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::eigen;

    fn syn(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        (a, b)
    }

    #[test]
    fn preconditioner_gives_o1_condition_number() {
        let (a, _) = syn(2048, 12, 1);
        let mut rng = Rng::new(7);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::Srht,
            SketchKind::Gaussian,
            SketchKind::SparseEmbed,
        ] {
            let p = precondition(&a, kind, 480, &mut rng);
            let g = blas::gram(&a);
            let kappa = eigen::cond_preconditioned(&g, &p.r);
            assert!(
                kappa < 3.0,
                "{}: kappa(AR^-1) = {kappa}, expected O(1)",
                kind.name()
            );
        }
    }

    #[test]
    fn preconditioner_beats_raw_condition_number() {
        // ill-conditioned A: scale columns wildly
        let (mut a, _) = syn(1024, 8, 2);
        for i in 0..a.rows {
            for j in 0..a.cols {
                *a.at_mut(i, j) *= 10f64.powi(j as i32);
            }
        }
        let raw_kappa = eigen::cond(&a);
        assert!(raw_kappa > 1e5);
        let mut rng = Rng::new(3);
        let p = precondition(&a, SketchKind::CountSketch, 400, &mut rng);
        let g = blas::gram(&a);
        let kappa = eigen::cond_preconditioned(&g, &p.r);
        assert!(kappa < 5.0, "kappa {kappa}");
    }

    #[test]
    fn hd_transform_preserves_objective() {
        // ||HDAx - HDb|| == ||Ax - b|| for any x (H, D orthogonal) modulo
        // zero padding (which adds zero rows to both sides).
        let (a, b) = syn(500, 6, 4); // pads to 512
        let mut rng = Rng::new(5);
        let hd = hd_transform(&a, &b, &mut rng);
        assert_eq!(hd.n_pad, 512);
        let x = rng.gaussians(6);
        let f_orig = blas::residual_sq(&a, &b, &x);
        let f_hd = blas::residual_sq(&hd.hda, &hd.hdb, &x);
        assert!(
            (f_orig - f_hd).abs() < 1e-8 * (1.0 + f_orig),
            "{f_orig} vs {f_hd}"
        );
    }

    #[test]
    fn hd_transform_flattens_leverage() {
        // row norms of HDA are far more uniform than those of a spiky A
        let mut a = Mat::zeros(256, 4);
        for j in 0..4 {
            *a.at_mut(j, j) = 10.0;
        }
        let b = vec![0.0; 256];
        let mut rng = Rng::new(6);
        let hd = hd_transform(&a, &b, &mut rng);
        let norms: Vec<f64> = (0..hd.hda.rows)
            .map(|i| blas::nrm2(hd.hda.row(i)))
            .collect();
        let max = norms.iter().cloned().fold(0.0, f64::max);
        let mean = norms.iter().sum::<f64>() / norms.len() as f64;
        assert!(
            max / mean < 6.0,
            "row norms still spiky: max {max}, mean {mean}"
        );
    }

    #[test]
    fn streamed_precondition_matches_dense_r() {
        // R from the block-streamed parallel sketch must equal R from the
        // dense single-pass apply to 1e-12, for every construction
        let (a, _) = syn(1024, 10, 9);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::Gaussian,
            SketchKind::SparseEmbed,
            SketchKind::Srht,
        ] {
            // dense reference, sketch sampled from an identical rng stream
            let mut r1 = Rng::new(42);
            let sk = kind.build(300, a.rows, &mut r1);
            let dense_r = qr::qr_r(&sk.apply(&a));
            let mut r2 = Rng::new(42);
            let be = Backend::native_with(4, None);
            let p = precondition_with(&be, &a, kind, 300, &mut r2, Some(128));
            assert!(
                p.r.max_abs_diff(&dense_r) < 1e-12,
                "{}: streamed R != dense R",
                kind.name()
            );
        }
    }

    #[test]
    fn csr_precondition_matches_dense_within_reassociation() {
        let mut rng = Rng::new(21);
        let dense = Mat::from_fn(600, 8, |_, _| {
            if rng.uniform() < 0.25 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let be = Backend::native_with(4, None);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::SparseEmbed,
            SketchKind::Gaussian,
            SketchKind::Srht,
        ] {
            let mut r1 = Rng::new(77);
            let p_dense = precondition_with(&be, &dense, kind, 160, &mut r1, Some(64));
            let mut r2 = Rng::new(77);
            let p_csr = precondition_csr_with(&be, &csr, kind, 160, &mut r2, Some(64));
            assert!(
                p_csr.r.max_abs_diff(&p_dense.r) < 1e-10,
                "{}: csr R != dense R",
                kind.name()
            );
            // and the rng streams end in the same state (same sketch draws)
            assert_eq!(r1.next_u64(), r2.next_u64(), "{}", kind.name());
        }
    }

    #[test]
    fn ds_precondition_routes_by_representation() {
        let mut rng = Rng::new(23);
        let dense = Mat::from_fn(300, 5, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(300);
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let ds_sparse = crate::data::Dataset::from_csr("sp", csr, b.clone(), None);
        let ds_dense = crate::data::Dataset::dense("dn", dense, b, None);
        let be = Backend::native();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let ps = precondition_ds_with(&be, &ds_sparse, SketchKind::CountSketch, 80, &mut r1, None);
        let pd = precondition_ds_with(&be, &ds_dense, SketchKind::CountSketch, 80, &mut r2, None);
        assert!(ps.r.max_abs_diff(&pd.r) < 1e-10);
        // step 1 on CSR never touches a dense view
        assert!(ds_sparse.dense_if_ready().is_none());
    }

    #[test]
    fn hd_transform_ds_is_charged_and_representation_aware() {
        let mut rng = Rng::new(31);
        let dense = Mat::from_fn(200, 6, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(200);
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let ds_sparse = crate::data::Dataset::from_csr("sp", csr, b.clone(), None);
        let ds_dense = crate::data::Dataset::dense("dn", dense.clone(), b.clone(), None);
        let be = Backend::native();
        let budget = crate::util::mem::MemBudget::unlimited();
        // dense route is bit-identical to the plain entry point
        let mut r1 = Rng::new(8);
        let mut r2 = Rng::new(8);
        let plain = hd_transform_with(&be, &dense, &b, &mut r1);
        let via_ds = hd_transform_ds_with(&be, &ds_dense, &mut r2, &budget, "t").unwrap();
        assert_eq!(plain.hda.max_abs_diff(&via_ds.hda), 0.0);
        assert_eq!(plain.hdb, via_ds.hdb);
        assert_eq!(budget.densify_events(), 0, "dense HD is not a densification");
        // CSR route builds the padded buffer straight from CSR: same bits,
        // one densify event, NO mirror left behind
        let mut r3 = Rng::new(8);
        let via_csr = hd_transform_ds_with(&be, &ds_sparse, &mut r3, &budget, "t").unwrap();
        assert_eq!(via_csr.hda.max_abs_diff(&plain.hda), 0.0);
        assert_eq!(via_csr.hdb, plain.hdb);
        assert_eq!(budget.densify_events(), 1);
        assert!(ds_sparse.dense_if_ready().is_none(), "no mirror materialized");
        // the charge covers the padded buffer and releases with the result
        let n_pad = 200usize.next_power_of_two();
        assert_eq!(budget.used(), 2 * n_pad * 7 * 8, "both HD results resident");
        drop(via_ds);
        drop(via_csr);
        assert_eq!(budget.used(), 0);
        // over budget: structured error, nothing allocated or counted extra
        let tight = crate::util::mem::MemBudget::with_limit_mb(1);
        let _hog = tight.try_charge((1 << 20) - 64, "hog").unwrap();
        let mut r4 = Rng::new(8);
        let err = hd_transform_ds_with(&be, &ds_sparse, &mut r4, &tight, "hd").unwrap_err();
        let me = err
            .downcast_ref::<MemError>()
            .expect("over-budget HD surfaces the structured MemError");
        assert_eq!(me.stage, "hd");
        assert_eq!(tight.densify_events(), 0);
    }

    #[test]
    fn budgeted_srht_on_csr_is_a_tracked_scoped_densify() {
        let mut rng = Rng::new(41);
        let dense = Mat::from_fn(256, 6, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(256);
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let ds = crate::data::Dataset::from_csr("sp", csr, b, None);
        let be = Backend::native();
        let budget = crate::util::mem::MemBudget::unlimited();
        // same rng stream: the budgeted route equals the sketch-layer
        // fallback bit for bit (both reduce to sk.apply on the same dense)
        let mut r1 = Rng::new(9);
        let p_plain = precondition_ds_with(&be, &ds, SketchKind::Srht, 64, &mut r1, None);
        let mut r2 = Rng::new(9);
        let p_budgeted =
            precondition_ds_budgeted(&be, &ds, SketchKind::Srht, 64, &mut r2, None, &budget)
                .unwrap();
        assert_eq!(p_budgeted.r.max_abs_diff(&p_plain.r), 0.0);
        // the transient view was charged, counted, and fully released
        assert_eq!(budget.densify_events(), 1);
        assert_eq!(budget.peak(), 256 * 6 * 8);
        assert_eq!(budget.used(), 0, "scoped view released on drop");
        assert!(ds.dense_if_ready().is_none(), "scoped view must not cache");
        // streaming kinds charge nothing through the budgeted route
        let mut r3 = Rng::new(9);
        let _ = precondition_ds_budgeted(
            &be,
            &ds,
            SketchKind::CountSketch,
            64,
            &mut r3,
            None,
            &budget,
        )
        .unwrap();
        assert_eq!(budget.densify_events(), 1);
        // over budget: structured error, never a panic
        let tight = crate::util::mem::MemBudget::with_limit_mb(1);
        let _hog = tight.try_charge((1 << 20) - 64, "hog").unwrap();
        let mut r4 = Rng::new(9);
        assert!(
            precondition_ds_budgeted(&be, &ds, SketchKind::Srht, 64, &mut r4, None, &tight)
                .is_err()
        );
    }

    #[test]
    fn blockwise_gather_matches_reference_bitwise() {
        let mut rng = Rng::new(61);
        let dense = Mat::from_fn(300, 9, |_, _| {
            if rng.uniform() < 0.2 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(300);
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let ds = crate::data::Dataset::from_csr("sp", csr.clone(), b.clone(), None);
        let mut r1 = Rng::new(13);
        let hd = hd_implicit_ds(&ds, &mut r1);
        let idx: Vec<usize> = (0..97).map(|_| (rng.next_u64() % 512) as usize).collect();
        let (wm, wb) = hd.gather_rows_csr_ref(&csr, &b, &idx);
        for block in [0usize, 1, 7, 32, 97, 128, 500] {
            let (gm, gb) = hd.gather_rows_csr_blocked(&csr, &b, &idx, block);
            assert_eq!(gm.max_abs_diff(&wm), 0.0, "block={block}");
            assert_eq!(
                gb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                wb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "block={block}"
            );
        }
        // default entry delegates to the blockwise path
        let (dm, db) = hd.gather_rows_csr(&csr, &b, &idx);
        assert_eq!(dm.max_abs_diff(&wm), 0.0);
        assert_eq!(db, wb);
    }

    #[test]
    #[should_panic(expected = "outside the padded universe")]
    fn gather_rejects_out_of_range_sample_index() {
        // promoted from debug_assert!: a corrupt sample index must be a hard
        // error in release builds too, never a silent row alias
        let mut rng = Rng::new(62);
        let dense = Mat::from_fn(50, 3, |_, _| rng.gaussian());
        let b = rng.gaussians(50);
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let ds = crate::data::Dataset::from_csr("sp", csr.clone(), b.clone(), None);
        let mut r1 = Rng::new(14);
        let hd = hd_implicit_ds(&ds, &mut r1);
        let _ = hd.gather_rows_csr(&csr, &b, &[64]); // n_pad = 64, so 64 is out
    }

    #[test]
    fn resolve_step2_auto_never_picks_dense_over_budget() {
        let mut rng = Rng::new(63);
        let dense = Mat::from_fn(256, 6, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(256);
        let sparse_ds = crate::data::Dataset::from_csr(
            "sp",
            crate::linalg::CsrMat::from_dense(&dense),
            b.clone(),
            None,
        );
        let dense_ds = crate::data::Dataset::dense("dn", dense, b, None);
        let unlimited = crate::util::mem::MemBudget::unlimited();

        // pins resolve verbatim, budget or not
        assert_eq!(
            resolve_step2(Step2Policy::Repr, &sparse_ds, 1, &unlimited),
            (Step2Mode::Repr, "implicit".into())
        );
        assert_eq!(
            resolve_step2(Step2Policy::Repr, &dense_ds, 1, &unlimited),
            (Step2Mode::Repr, "dense".into())
        );
        assert_eq!(
            resolve_step2(Step2Policy::Dense, &sparse_ds, 1, &unlimited),
            (Step2Mode::Dense, "dense".into())
        );
        assert_eq!(
            resolve_step2(Step2Policy::Implicit, &sparse_ds, 1, &unlimited),
            (Step2Mode::Implicit, "implicit".into())
        );
        // dense data: auto is the materialized (bit-exact) form
        assert_eq!(
            resolve_step2(Step2Policy::Auto, &dense_ds, 1 << 20, &unlimited),
            (Step2Mode::Repr, "auto→dense".into())
        );
        // enough sampled rows: the one-time FWHT amortizes, dense wins
        let (mode, label) = resolve_step2(Step2Policy::Auto, &sparse_ds, 10_000, &unlimited);
        assert_eq!((mode, label.as_str()), (Step2Mode::Dense, "auto→dense"));
        // few sampled rows: materializing never pays for itself
        let (mode, label) = resolve_step2(Step2Policy::Auto, &sparse_ds, 1, &unlimited);
        assert_eq!((mode, label.as_str()), (Step2Mode::Repr, "auto→implicit"));
        // same dense-favoring workload under memory pressure: auto must
        // degrade to implicit, never resolve to a buffer it cannot charge
        let tight = crate::util::mem::MemBudget::with_limit_mb(1);
        let hog = tight.try_charge((1 << 20) - 4096, "hog").unwrap();
        assert!(!tight.would_fit(hd_buffer_bytes(256, 6)));
        let (mode, label) = resolve_step2(Step2Policy::Auto, &sparse_ds, 10_000, &tight);
        assert_eq!((mode, label.as_str()), (Step2Mode::Repr, "auto→implicit"));
        drop(hog);
        // headroom back: the same call flips to dense again
        let (mode, _) = resolve_step2(Step2Policy::Auto, &sparse_ds, 10_000, &tight);
        assert_eq!(mode, Step2Mode::Dense);
    }

    #[test]
    fn hd_with_backend_matches_wrapper() {
        let (a, b) = syn(300, 4, 11); // pads to 512
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let via_wrapper = hd_transform(&a, &b, &mut r1);
        let via_backend = hd_transform_with(&Backend::native(), &a, &b, &mut r2);
        assert_eq!(via_wrapper.n_pad, via_backend.n_pad);
        assert_eq!(via_wrapper.hdb, via_backend.hdb);
        assert!(via_wrapper.hda.max_abs_diff(&via_backend.hda) == 0.0);
    }

    #[test]
    fn timings_are_recorded() {
        let (a, b) = syn(1024, 8, 7);
        let mut rng = Rng::new(8);
        let p = precondition(&a, SketchKind::CountSketch, 200, &mut rng);
        assert!(p.sketch_secs >= 0.0 && p.qr_secs >= 0.0);
        let hd = hd_transform(&a, &b, &mut rng);
        assert!(hd.secs >= 0.0);
    }
}
