//! Two-step preconditioning — the paper's core contribution.
//!
//! Step 1 (Algorithm 1): sketch `SA`, thin-QR it, keep `R`; `U = AR^{-1}` is
//! (O(sqrt d), O(1), 2)-conditioned, i.e. kappa(AR^{-1}) = O(1). We never
//! form U.
//!
//! Step 2 (Algorithm 2, step 2): apply the Randomized Hadamard Transform
//! `HD` to `[A | b]`, spreading row norms (Theorem 1) so *uniform*
//! mini-batch sampling has the variance bound of Lemma 9.

use crate::linalg::{qr, tri, Mat};
use crate::sketch::fwht::randomized_hadamard;
use crate::sketch::SketchKind;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

/// Output of step 1: the triangular preconditioner + timing for Table 2.
pub struct Precondition {
    /// Upper-triangular R from QR(SA): the preconditioner factor.
    pub r: Mat,
    /// Dense R^{-1}R^{-T} — shipped to the PJRT artifacts as `pinv`.
    pub pinv: Mat,
    /// Wall-clock cost of the sketch + QR (Table 2 measurements).
    pub sketch_secs: f64,
    pub qr_secs: f64,
    pub sketch_kind: SketchKind,
    pub sketch_rows: usize,
}

/// Step 1 of Algorithm 2/4/6: compute R such that AR^{-1} is
/// well-conditioned, via a sketch of the packed [A | b] (we sketch A only;
/// b is irrelevant to conditioning).
pub fn precondition(
    a: &Mat,
    kind: SketchKind,
    sketch_rows: usize,
    rng: &mut Rng,
) -> Precondition {
    assert!(sketch_rows > a.cols, "sketch size must exceed d");
    let t = Timer::start();
    let sk = kind.build(sketch_rows, a.rows, rng);
    let sa = sk.apply(a);
    let sketch_secs = t.secs();
    let t = Timer::start();
    let r = qr::qr_r(&sa);
    let pinv = tri::pinv_dense(&r);
    let qr_secs = t.secs();
    Precondition {
        r,
        pinv,
        sketch_secs,
        qr_secs,
        sketch_kind: kind,
        sketch_rows,
    }
}

/// Step 2: the Randomized Hadamard Transform applied to [A | b] packed as an
/// n x (d+1) matrix. Pads n to a power of two. Returns (HDA, HDb, n_pad).
///
/// Padding note: FWHT needs 2^k rows; padding appends zero rows, which are
/// valid "samples" of the transformed system (they contribute zero
/// gradient in expectation scaled consistently) — we keep the *padded* row
/// count as the sampling universe exactly like zero-padding the dataset.
pub struct HdTransformed {
    pub hda: Mat,
    pub hdb: Vec<f64>,
    /// padded row count (sampling universe size)
    pub n_pad: usize,
    pub secs: f64,
}

pub fn hd_transform(a: &Mat, b: &[f64], rng: &mut Rng) -> HdTransformed {
    assert_eq!(a.rows, b.len());
    let t = Timer::start();
    let bmat = Mat::from_vec(b.len(), 1, b.to_vec());
    let packed = a.hstack(&bmat);
    let n_pad = packed.rows.next_power_of_two();
    let mut padded = if n_pad == packed.rows {
        packed
    } else {
        packed.pad_rows(n_pad)
    };
    let signs = rng.signs(n_pad);
    randomized_hadamard(&mut padded, &signs);
    let (hda, hdb) = padded.split_last_col();
    HdTransformed {
        hda,
        hdb,
        n_pad,
        secs: t.secs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::eigen;

    fn syn(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        (a, b)
    }

    #[test]
    fn preconditioner_gives_o1_condition_number() {
        let (a, _) = syn(2048, 12, 1);
        let mut rng = Rng::new(7);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::Srht,
            SketchKind::Gaussian,
            SketchKind::SparseEmbed,
        ] {
            let p = precondition(&a, kind, 480, &mut rng);
            let g = blas::gram(&a);
            let kappa = eigen::cond_preconditioned(&g, &p.r);
            assert!(
                kappa < 3.0,
                "{}: kappa(AR^-1) = {kappa}, expected O(1)",
                kind.name()
            );
        }
    }

    #[test]
    fn preconditioner_beats_raw_condition_number() {
        // ill-conditioned A: scale columns wildly
        let (mut a, _) = syn(1024, 8, 2);
        for i in 0..a.rows {
            for j in 0..a.cols {
                *a.at_mut(i, j) *= 10f64.powi(j as i32);
            }
        }
        let raw_kappa = eigen::cond(&a);
        assert!(raw_kappa > 1e5);
        let mut rng = Rng::new(3);
        let p = precondition(&a, SketchKind::CountSketch, 400, &mut rng);
        let g = blas::gram(&a);
        let kappa = eigen::cond_preconditioned(&g, &p.r);
        assert!(kappa < 5.0, "kappa {kappa}");
    }

    #[test]
    fn hd_transform_preserves_objective() {
        // ||HDAx - HDb|| == ||Ax - b|| for any x (H, D orthogonal) modulo
        // zero padding (which adds zero rows to both sides).
        let (a, b) = syn(500, 6, 4); // pads to 512
        let mut rng = Rng::new(5);
        let hd = hd_transform(&a, &b, &mut rng);
        assert_eq!(hd.n_pad, 512);
        let x = rng.gaussians(6);
        let f_orig = blas::residual_sq(&a, &b, &x);
        let f_hd = blas::residual_sq(&hd.hda, &hd.hdb, &x);
        assert!(
            (f_orig - f_hd).abs() < 1e-8 * (1.0 + f_orig),
            "{f_orig} vs {f_hd}"
        );
    }

    #[test]
    fn hd_transform_flattens_leverage() {
        // row norms of HDA are far more uniform than those of a spiky A
        let mut a = Mat::zeros(256, 4);
        for j in 0..4 {
            *a.at_mut(j, j) = 10.0;
        }
        let b = vec![0.0; 256];
        let mut rng = Rng::new(6);
        let hd = hd_transform(&a, &b, &mut rng);
        let norms: Vec<f64> = (0..hd.hda.rows)
            .map(|i| blas::nrm2(hd.hda.row(i)))
            .collect();
        let max = norms.iter().cloned().fold(0.0, f64::max);
        let mean = norms.iter().sum::<f64>() / norms.len() as f64;
        assert!(
            max / mean < 6.0,
            "row norms still spiky: max {max}, mean {mean}"
        );
    }

    #[test]
    fn timings_are_recorded() {
        let (a, b) = syn(1024, 8, 7);
        let mut rng = Rng::new(8);
        let p = precondition(&a, SketchKind::CountSketch, 200, &mut rng);
        assert!(p.sketch_secs >= 0.0 && p.qr_secs >= 0.0);
        let hd = hd_transform(&a, &b, &mut rng);
        assert!(hd.secs >= 0.0);
    }
}
