//! `hdpw` — the coordinator binary.
//!
//! Subcommands:
//!   solve       run one regression job and print the report
//!   serve       run the solver service (TCP or stdio)
//!   experiment  run a paper experiment (fig1..fig6, table1, table2)
//!   datasets    describe the built-in datasets (Table 3)
//!   artifacts   inspect the AOT artifact manifest
//!   bench-info  print backend/dispatch information

use hdpw::backend::Backend;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
use hdpw::experiments::{self, ExpCtx};
use hdpw::runtime::Engine;
use hdpw::util::cli::Command;
use hdpw::util::logging;
use std::sync::Arc;

fn main() {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match argv.split_first() {
        Some((s, rest)) => (s.as_str(), rest.to_vec()),
        None => {
            print_usage();
            std::process::exit(2);
        }
    };
    let code = match sub {
        "solve" => cmd_solve(&rest),
        "serve" => cmd_serve(&rest),
        "experiment" => cmd_experiment(&rest),
        "datasets" => cmd_datasets(&rest),
        "artifacts" => cmd_artifacts(&rest),
        "bench-info" => cmd_bench_info(&rest),
        "help" | "--help" | "-h" => {
            print_usage();
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    eprintln!(
        "hdpw — large-scale constrained linear regression via two-step preconditioning

usage: hdpw <subcommand> [options]

subcommands:
  solve        run one regression job           (hdpw solve --help)
  serve        run the solver service           (hdpw serve --help)
  experiment   regenerate a paper table/figure  (hdpw experiment fig1)
  datasets     list built-in datasets (Table 3)
  artifacts    inspect the AOT artifact manifest
  bench-info   print backend information"
    );
}

fn parse_or_exit(cmd: &Command, argv: &[String]) -> hdpw::util::cli::Args {
    match cmd.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn cmd_solve(argv: &[String]) -> i32 {
    let cmd = Command::new("hdpw solve", "run one regression job")
        .opt(
            "dataset",
            "syn1|syn2|year|buzz|pjrt8k|csv:<path>|libsvm:<path>|mmapdense:<file>|\
             libsvm-chunked:<dir> (default syn2)",
        )
        .opt(
            "format",
            "dense|sparse|libsvm|mmapdense|libsvm-chunked dataset representation \
             (default dense; HDPW_FORMAT overrides; the last two stream from disk)",
        )
        .opt(
            "density",
            "target nnz fraction for generated sparse datasets (default 0.1)",
        )
        .opt(
            "chunk-rows",
            "rows per on-disk shard for mmapdense/libsvm-chunked (0 = format default)",
        )
        .opt("n", "rows for generated datasets (default 16384)")
        .opt("solver", "solver name (default hdpwbatchsgd)")
        .opt(
            "constraint",
            "unc|l1[:r]|l2[:r]|nonneg|simplex[:total]|box:lo,hi|enet:alpha[,r] \
             or a JSON spec like {\"box\":{\"lo\":[...],\"hi\":[...]}} (default unc)",
        )
        .opt("radius", "ball radius (default: norm of unconstrained optimum)")
        .opt("batch-size", "mini-batch size r (default 64)")
        .opt("max-iters", "iteration cap (default 5000)")
        .opt("time-budget", "seconds (default 30)")
        .opt("target-rel-err", "stop at this relative error")
        .opt("trials", "best-of-k trials (default 1; paper uses 10)")
        .opt("seed", "rng seed (default 1)")
        .opt("sketch", "gaussian|srht|countsketch|sparse (default countsketch)")
        .opt("sketch-size", "sketch rows s (default auto)")
        .opt("eta", "fixed step size (default: theory)")
        .opt(
            "step2",
            "repr|dense|implicit|auto HD-transform representation policy \
             (default repr; auto = nnz-aware cost model)",
        )
        .opt("executor", "default|native|simd|auto|pjrt (per-request backend)")
        .opt("block-rows", "row-shard height for streamed setup (default auto)")
        .opt("priority", "high|normal|batch scheduler lane (default normal)")
        .opt(
            "deadline-ms",
            "shed the job (structured error) if it cannot start in time (0 = no deadline)",
        )
        .opt(
            "mem-mb",
            "memory budget for dense materializations in MiB (0 = unlimited; HDPW_MEM_MB default)",
        )
        .flag_opt("normalize", "normalize the dataset first (scale-only on sparse data)")
        .flag_opt("reuse-precond", "reuse the preconditioner across trials via the artifact cache")
        .flag_opt("warm-start", "start trials after the first from the best iterate so far")
        .flag_opt("native", "force the native backend (skip PJRT artifacts)")
        .flag_opt("json", "emit the result as JSON");
    let args = parse_or_exit(&cmd, argv);

    let mut req = JobRequest::default();
    req.dataset = args.get_or("dataset", "syn2");
    req.n = args.get_usize("n", req.n);
    req.solver = args.get_or("solver", "hdpwbatchsgd");
    req.constraint = match args.get_or("constraint", "unc").parse() {
        Ok(spec) => spec,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    req.radius = args.get_f64("radius", 0.0);
    req.batch_size = args.get_usize("batch-size", req.batch_size);
    req.max_iters = args.get_usize("max-iters", req.max_iters);
    req.time_budget = args.get_f64("time-budget", req.time_budget);
    req.target_rel_err = args.get_f64("target-rel-err", 0.0);
    req.trials = args.get_usize("trials", 1);
    req.seed = args.get_u64("seed", 1);
    req.sketch = args.get_or("sketch", "countsketch");
    req.sketch_size = args.get_usize("sketch-size", 0);
    req.eta = args.get_f64("eta", 0.0);
    if let Some(s) = args.get("step2") {
        req.step2 = s.to_string();
    }
    req.executor = args.get_or("executor", "default");
    req.block_rows = args.get_usize("block-rows", 0);
    if let Some(p) = args.get("priority") {
        req.priority = p.to_string();
    }
    req.deadline_ms = args.get_f64("deadline-ms", req.deadline_ms);
    // default honors the HDPW_FORMAT process default baked into the request
    if let Some(fmt) = args.get("format") {
        req.format = fmt.to_string();
    }
    req.density = args.get_f64("density", req.density);
    req.chunk_rows = args.get_usize("chunk-rows", req.chunk_rows);
    req.normalize = args.flag("normalize");
    // flags OR onto the env-driven defaults (HDPW_REUSE_PRECOND / _WARM_START)
    req.reuse_precond |= args.flag("reuse-precond");
    req.warm_start |= args.flag("warm-start");
    if args.get("mem-mb").is_some() {
        hdpw::util::mem::MemBudget::process().set_limit_mb(args.get_usize("mem-mb", 0));
    }

    let backend = if args.flag("native") {
        Backend::native()
    } else {
        Backend::auto()
    };
    let pjrt = backend.has_pjrt();
    let fallback = backend.pjrt_fallback_reason();
    let coord = Arc::new(Coordinator::new(backend, CoordinatorConfig::default()));
    // route through the serve-tier submit path so --priority/--deadline-ms
    // get the same lane routing + deadline shedding a served request would
    let n = req.n;
    let executor = req.executor.clone();
    let (tx, rx) = std::sync::mpsc::channel();
    coord.submit(req, move |res| {
        let _ = tx.send(res);
    });
    let result = rx.recv().expect("the worker pool delivers a result");
    match result {
        Ok(res) => {
            if args.flag("json") {
                println!("{}", res.to_json());
            } else {
                println!("solver     : {}", res.solver);
                println!("dataset    : {} (n={})", res.dataset, n);
                // reflect the effective per-request executor, not just the
                // process-wide backend
                println!(
                    "backend    : {}",
                    match executor.as_str() {
                        "native" => "native (forced per-request)",
                        "simd" => "simd+native (forced per-request)",
                        _ if pjrt => "pjrt+native",
                        _ if hdpw::simd::preferred() => "simd+native",
                        _ => "native",
                    }
                );
                if let Some(reason) = &fallback {
                    println!("pjrt fell back: {reason}");
                }
                if res.constraint != "unc" {
                    println!(
                        "constraint : {}{} projections={}",
                        res.constraint,
                        if res.constraint_params.is_empty() {
                            String::new()
                        } else {
                            format!(" ({})", res.constraint_params)
                        },
                        res.projections
                    );
                }
                if res.sparse {
                    println!(
                        "sparse     : nnz={} density={:.4} (CSR pipeline)",
                        res.nnz, res.density
                    );
                }
                if res.mem_est_bytes > 0 || res.densify_events > 0 {
                    println!(
                        "mem        : est={}B peak={}B densify_events={}",
                        res.mem_est_bytes, res.mem_peak_bytes, res.densify_events
                    );
                }
                if res.shard_faults > 0 || res.io_retries > 0 {
                    println!(
                        "out-of-core: shard_faults={} evictions={} io_retries={}",
                        res.shard_faults, res.shard_evictions, res.io_retries
                    );
                }
                println!("f*         : {:.6e}", res.f_star);
                println!("f(best)    : {:.6e}", res.best_f);
                println!("rel error  : {:.3e}", res.best_rel_err);
                if res.best.precond_cache != hdpw::precond::CacheOutcome::Off {
                    println!("precond    : {} (artifact cache)", res.best.precond_cache.as_str());
                }
                if !res.best.step2.is_empty() {
                    println!("step2      : {}", res.best.step2);
                }
                if res.batched_trials > 1 || res.batched_requests > 1 {
                    println!(
                        "batched    : trials={} requests={}",
                        res.batched_trials, res.batched_requests
                    );
                }
                println!("iters      : {}", res.best.iters);
                println!(
                    "setup/solve: {} / {}",
                    hdpw::util::stats::fmt_duration(res.best.setup_secs),
                    hdpw::util::stats::fmt_duration(res.best.solve_secs)
                );
                println!("trials     : {}", res.trials_run);
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cmd = Command::new("hdpw serve", "run the solver service")
        .opt("addr", "TCP listen address (default 127.0.0.1:7878)")
        .opt("workers", "concurrent jobs (default 2)")
        .opt("max-queue", "queue bound for backpressure (default 16)")
        .opt(
            "precond-cache-mb",
            "preconditioner artifact cache budget in MiB (default 256)",
        )
        .opt(
            "mem-mb",
            "hard memory budget for dense materializations in MiB (0 = unlimited; \
             over-budget jobs get a structured error instead of OOMing a worker)",
        )
        .flag_opt("stdio", "serve stdin/stdout instead of TCP")
        .flag_opt("native", "force the native backend");
    let args = parse_or_exit(&cmd, argv);
    let backend = if args.flag("native") {
        Backend::native()
    } else {
        Backend::auto()
    };
    let default_cache_mb = hdpw::precond::PrecondCache::default_budget() >> 20;
    // --mem-mb re-limits the process budget (HDPW_MEM_MB default), which is
    // the budget the coordinator's admission control and all solves charge
    if args.get("mem-mb").is_some() {
        hdpw::util::mem::MemBudget::process().set_limit_mb(args.get_usize("mem-mb", 0));
    }
    let coord = Arc::new(Coordinator::new(
        backend,
        CoordinatorConfig {
            workers: args.get_usize("workers", 2),
            max_queue: args.get_usize("max-queue", 16),
            cache_dir: Some(std::path::PathBuf::from(".hdpw_cache")),
            precond_cache_bytes: args
                .get_usize("precond-cache-mb", default_cache_mb)
                .max(1)
                << 20,
            ..CoordinatorConfig::default()
        },
    ));
    let result = if args.flag("stdio") {
        hdpw::coordinator::server::serve_stdio(coord)
    } else {
        let addr = args.get_or("addr", "127.0.0.1:7878");
        hdpw::coordinator::server::serve_tcp(coord, &addr)
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve error: {e:#}");
            1
        }
    }
}

fn cmd_experiment(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "hdpw experiment",
        "regenerate a paper table/figure (positional: fig1..fig6 | table1 | table2 | all)",
    )
    .opt("n", "dataset rows (default 65536; quick: 8192)")
    .opt("trials", "best-of-k (default 10; quick: 3)")
    .opt("budget", "seconds per solver run")
    .flag_opt("quick", "small fast configuration");
    let args = parse_or_exit(&cmd, argv);
    let which = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let mut ctx = ExpCtx::new(args.flag("quick"));
    ctx.n = args.get_usize("n", ctx.n);
    ctx.trials = args.get_usize("trials", ctx.trials);
    ctx.budget = args.get_f64("budget", ctx.budget);

    let run_one = |ctx: &ExpCtx, name: &str| -> anyhow::Result<()> {
        match name {
            "fig1" => {
                let out = experiments::fig1::run(ctx)?;
                for (i, fig) in out.figures.iter().enumerate() {
                    println!("{}", ctx.save_and_render(fig, &format!("fig1_{i}")));
                }
                println!("{}", experiments::fig1::render_table(&out));
            }
            "fig2" => {
                let panels = experiments::fig2::run(ctx)?;
                println!("{}", ctx.save_and_render(&panels.low, "fig2_low"));
                println!("{}", ctx.save_and_render(&panels.high, "fig2_high"));
            }
            "fig3" | "fig4" | "fig5" | "fig6" => {
                let figs = match name {
                    "fig3" => experiments::figs_real::fig3(ctx)?,
                    "fig4" => experiments::figs_real::fig4(ctx)?,
                    "fig5" => experiments::figs_real::fig5(ctx)?,
                    _ => experiments::figs_real::fig6(ctx)?,
                };
                for (i, fig) in figs.iter().enumerate() {
                    println!("{}", ctx.save_and_render(fig, &format!("{name}_{i}")));
                }
            }
            "table1" => {
                let out = experiments::table1::run(ctx)?;
                println!("{}", experiments::table1::render(&out));
                let v = experiments::table1::verdict(&out);
                println!(
                    "verdict: batch_speedup={} linear_convergence={}",
                    v.batch_speedup_ok, v.linear_convergence_ok
                );
            }
            "table2" => {
                let out = experiments::table2::run(ctx)?;
                println!("{}", experiments::table2::render(&out));
            }
            other => anyhow::bail!("unknown experiment {other:?}"),
        }
        Ok(())
    };

    let names: Vec<&str> = if which == "all" {
        vec![
            "table2", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "table1",
        ]
    } else {
        vec![which.as_str()]
    };
    for name in names {
        println!("===== {name} =====");
        if let Err(e) = run_one(&ctx, name) {
            eprintln!("experiment {name} failed: {e:#}");
            return 1;
        }
    }
    0
}

fn cmd_datasets(_argv: &[String]) -> i32 {
    println!("built-in datasets (Table 3 of the paper; generated, see DESIGN.md section 7):");
    println!(
        "{:<8} {:>10} {:>8} {:>12} {:>14} note",
        "name", "rows*", "cols", "kappa", "sketch size"
    );
    for (name, d, kappa, note) in [
        ("syn1", 20, "1e8", "exact spectrum"),
        ("syn2", 20, "1e3", "exact spectrum"),
        ("year", 90, "~3e3", "UCI Year simulated"),
        ("buzz", 77, "~1e8", "UCI Buzz simulated (heavy tails)"),
        ("pjrt8k", 32, "1e6", "canonical AOT-artifact shape"),
    ] {
        let n = hdpw::data::uci_sim::paper_scale_n(name);
        let s = hdpw::sketch::default_sketch_size(n, d);
        println!("{name:<8} {n:>10} {d:>8} {kappa:>12} {s:>14} {note}");
    }
    println!("* paper-scale rows; every command accepts --n to rescale");
    println!(
        "sparse variants: --format sparse|libsvm generates the CSR twin of any \
         name above (--density, default 0.1); --dataset libsvm:<path> loads a file"
    );
    println!(
        "out-of-core: --format mmapdense|libsvm-chunked spills the generated data \
         to disk and streams it through the shard cache (--chunk-rows); \
         --dataset mmapdense:<file>|libsvm-chunked:<dir> loads existing files"
    );
    0
}

fn cmd_artifacts(_argv: &[String]) -> i32 {
    match Engine::load(&Engine::default_dir()) {
        Ok(engine) => {
            let meta = &engine.manifest_meta;
            println!(
                "artifacts at {:?}: canonical n={} d={} rs={:?} chunk_t={} pw_t={}",
                engine.dir, meta.n, meta.d, meta.rs, meta.chunk_t, meta.pw_t
            );
            for name in engine.op_names() {
                let sig = engine.signature(name).unwrap();
                println!(
                    "  {name:<44} inputs={} outputs={}",
                    sig.inputs.len(),
                    sig.outputs
                );
            }
            0
        }
        Err(e) => {
            eprintln!("no artifacts: {e:#}");
            1
        }
    }
}

fn cmd_bench_info(_argv: &[String]) -> i32 {
    let backend = Backend::auto();
    println!("pjrt artifacts : {}", backend.has_pjrt());
    if let Some(reason) = backend.pjrt_fallback_reason() {
        println!("pjrt fallback  : {reason}");
    }
    println!(
        "simd           : {} ({} f64 lanes, HDPW_SIMD override), registered: {}",
        hdpw::simd::arch().name(),
        hdpw::simd::lanes(),
        backend.has_simd()
    );
    println!(
        "threads        : {}",
        hdpw::util::threadpool::default_threads()
    );
    println!(
        "pool fallbacks : {} (busy data-parallel pool ran a loop serially \
         inline; a hot counter means nested parallelism is eating cores)",
        hdpw::util::threadpool::static_pool().serial_fallbacks()
    );
    println!(
        "block heuristic: {} rows for a 2^17 x 50 workload",
        hdpw::data::default_block_rows(1 << 17, 50)
    );
    println!(
        "precond cache  : {} MiB budget (HDPW_PRECOND_CACHE_MB), reuse default {}",
        hdpw::precond::PrecondCache::default_budget() >> 20,
        if hdpw::coordinator::job::env_flag("HDPW_REUSE_PRECOND") {
            "on (HDPW_REUSE_PRECOND)"
        } else {
            "off (paper protocol)"
        }
    );
    let mem = hdpw::util::mem::MemBudget::process();
    println!(
        "mem budget     : {} (HDPW_MEM_MB / --mem-mb), peak {} B, densify_events {}",
        match mem.limit_bytes() {
            Some(b) => format!("{} MiB", b >> 20),
            None => "unlimited".into(),
        },
        mem.peak(),
        mem.densify_events()
    );
    println!(
        "shard cache    : faults {}, evictions {}, io_retries {}, resident {} B \
         (out-of-core formats: mmapdense / libsvm-chunked)",
        mem.shard_faults(),
        mem.shard_evictions(),
        mem.io_retries(),
        mem.shard_resident_bytes()
    );
    0
}
