//! Offline drop-in subset of the `anyhow` crate.
//!
//! This repo builds in network-isolated CI, so instead of pulling the real
//! crate from crates.io we vendor the small slice of its API the codebase
//! uses: [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `bail!` / `ensure!` macros. Semantics match real anyhow where
//! it matters here:
//!
//! * `Display` prints the outermost message only; the alternate form `{:#}`
//!   prints the whole context chain joined by `": "`.
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`.
//! * `Error` itself intentionally does NOT implement `std::error::Error`
//!   (that would clash with the blanket `From` impl, exactly as upstream).

use std::fmt;

/// Error: an ordered chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Push an outer context message onto the chain.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirrors anyhow's debug rendering: message, then the cause chain.
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        // Flatten the source chain into our message chain.
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_vs_alternate() {
        let e: Error = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");
        let o: Option<u32> = None;
        let e2 = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e2}"), "missing 7");
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero not allowed");
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
        let e = anyhow!("plain {}", 1);
        assert_eq!(format!("{e}"), "plain 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", g().unwrap_err()), "gone");
    }
}
