//! Offline stub of the `xla` PJRT binding.
//!
//! The real crate links libxla/PJRT (a multi-GB C++ toolchain that is not
//! available in this build environment). The repo's runtime bridge only
//! needs two things from it:
//!
//! 1. **Literals** — host-side typed arrays used to marshal inputs/outputs.
//!    These are implemented for real (in memory), so every conversion and
//!    shape-checking path in `runtime::literal` behaves identically to a
//!    linked build.
//! 2. **The PJRT client / executable** — `PjRtClient::cpu()` returns an
//!    error stating the runtime is unavailable, which makes
//!    `EngineHandle::spawn` fail cleanly and `Backend::auto()` fall back to
//!    the native executor (the fallback reason is logged and surfaced in
//!    `DispatchStats`). Substituting a real binding restores the PJRT path
//!    without touching any repo code: point the `xla` dependency elsewhere.

use std::fmt;

/// Error type for all stub operations.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------------
// literals (fully functional)
// ---------------------------------------------------------------------------

/// Element buffer of a literal, tagged by dtype.
#[derive(Clone, Debug, PartialEq)]
enum Buf {
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F64(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::I64(v) => v.len(),
        }
    }
}

/// Sealed-ish element trait for the three dtypes the artifacts use.
pub trait NativeType: Copy {
    fn into_buf(data: Vec<Self>) -> Buf;
    fn from_buf(buf: &Buf) -> Option<Vec<Self>>;
}

impl NativeType for f64 {
    fn into_buf(data: Vec<Self>) -> Buf {
        Buf::F64(data)
    }
    fn from_buf(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::F64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_buf(data: Vec<Self>) -> Buf {
        Buf::I32(data)
    }
    fn from_buf(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i64 {
    fn into_buf(data: Vec<Self>) -> Buf {
        Buf::I64(data)
    }
    fn from_buf(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::I64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host literal: dims (row-major, major-to-minor) + typed buffer.
/// Tuples are a separate variant so `to_tuple` can unpack artifact outputs.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

#[derive(Clone, Debug, PartialEq)]
enum Payload {
    Array(Buf),
    Tuple(Vec<Literal>),
}

impl Literal {
    /// 0-d scalar literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: vec![],
            payload: Payload::Array(T::into_buf(vec![v])),
        }
    }

    /// 1-d literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            payload: Payload::Array(T::into_buf(data.to_vec())),
        }
    }

    /// Tuple literal (artifact outputs are lowered with return_tuple=True).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![],
            payload: Payload::Tuple(parts),
        }
    }

    /// Reinterpret with new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match &self.payload {
            Payload::Array(b) => b.len() as i64,
            Payload::Tuple(_) => {
                return Err(Error::new("cannot reshape a tuple literal"));
            }
        };
        if want != have {
            return Err(Error::new(format!(
                "reshape: {have} elements cannot view as {dims:?}"
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            payload: self.payload.clone(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Flat element copy-out (dtype must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.payload {
            Payload::Array(b) => {
                T::from_buf(b).ok_or_else(|| Error::new("literal dtype mismatch"))
            }
            Payload::Tuple(_) => Err(Error::new("literal is a tuple, not an array")),
        }
    }

    /// Unpack a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.payload {
            Payload::Tuple(parts) => Ok(parts.clone()),
            Payload::Array(_) => Err(Error::new("literal is an array, not a tuple")),
        }
    }
}

// ---------------------------------------------------------------------------
// runtime objects (stubbed: constructing a client reports unavailability)
// ---------------------------------------------------------------------------

const UNAVAILABLE: &str = "PJRT runtime unavailable: built against the vendored xla stub \
(link a real xla binding to enable the artifact path)";

/// HLO module handle. Text loading is accepted (the file is read so missing
/// artifacts still error first with a useful message); compilation is not.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client. `cpu()` always fails in the stub build.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_scalar_vec_reshape_roundtrip() {
        let s = Literal::scalar(2.5f64);
        assert_eq!(s.to_vec::<f64>().unwrap(), vec![2.5]);
        assert!(s.dims().is_empty());

        let v = Literal::vec1(&[1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = v.reshape(&[2, 3]).unwrap();
        assert_eq!(m.dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f64>().unwrap().len(), 6);
        assert!(v.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn literal_dtypes_are_checked() {
        let v = Literal::vec1(&[1i32, 2, 3]);
        assert!(v.to_vec::<f64>().is_err());
        assert_eq!(v.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn tuple_pack_unpack() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f64), Literal::vec1(&[2.0f64])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(parts[0].to_tuple().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
    }
}
