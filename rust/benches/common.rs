// Shared bench-entry helper (included by each bench via `include!`).
//
// `cargo bench` passes extra args (e.g. `--bench`); we accept
// HDPW_BENCH_FULL=1 to run at paper scale, default quick scale.

use hdpw::experiments::ExpCtx;

pub fn bench_ctx() -> ExpCtx {
    let full = std::env::var("HDPW_BENCH_FULL").ok().as_deref() == Some("1");
    let mut ctx = ExpCtx::new(!full);
    if let Ok(n) = std::env::var("HDPW_BENCH_N") {
        if let Ok(n) = n.parse() {
            ctx.n = n;
        }
    }
    if let Ok(t) = std::env::var("HDPW_BENCH_TRIALS") {
        if let Ok(t) = t.parse() {
            ctx.trials = t;
        }
    }
    eprintln!(
        "[bench] n={} trials={} budget={}s pjrt={}",
        ctx.n,
        ctx.trials,
        ctx.budget,
        ctx.coord.backend().has_pjrt()
    );
    ctx
}
