//! Regenerates Fig3 (see experiments::figs_real).
include!("common.rs");

fn main() {
    let ctx = bench_ctx();
    let figs = hdpw::experiments::figs_real::fig3(&ctx).expect("fig3");
    for (i, fig) in figs.iter().enumerate() {
        println!("{}", ctx.save_and_render(fig, &format!("fig3_{i}")));
    }
}
