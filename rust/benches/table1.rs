//! Regenerates Table 1 (empirical complexity scaling).
include!("common.rs");

fn main() {
    let ctx = bench_ctx();
    let out = hdpw::experiments::table1::run(&ctx).expect("table1");
    println!("{}", hdpw::experiments::table1::render(&out));
    let v = hdpw::experiments::table1::verdict(&out);
    println!(
        "verdict: batch_speedup={} linear_convergence={}",
        v.batch_speedup_ok, v.linear_convergence_ok
    );
}
