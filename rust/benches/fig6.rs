//! Regenerates Fig6 (see experiments::figs_real).
include!("common.rs");

fn main() {
    let ctx = bench_ctx();
    let figs = hdpw::experiments::figs_real::fig6(&ctx).expect("fig6");
    for (i, fig) in figs.iter().enumerate() {
        println!("{}", ctx.save_and_render(fig, &format!("fig6_{i}")));
    }
}
