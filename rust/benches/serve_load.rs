//! Serve-tier load generator (ISSUE 7 acceptance).
//!
//! Two phases:
//!   1. Coalescing proof — ≥8 concurrent same-key `reuse_precond` jobs must
//!      report `coalesced_batch > 1` while each job's solution stays
//!      bit-identical to the same request run alone (uncoalesced).
//!   2. Mixed load — hundreds/thousands of dense/sparse/constrained jobs
//!      cycling the high/normal/batch lanes through a `serve_stdio`-style
//!      `handle_connection`, reporting jobs/sec and per-lane p50/p95/p99 to
//!      `BENCH_serve.json`.
//!
//! Modes:
//!   default            — ~2000 jobs (HDPW_SERVE_JOBS overrides), plus
//!                        deadline pressure on the batch lane so shedding
//!                        is exercised and reported.
//!   HDPW_SERVE_SMOKE=1 — ~240 jobs, no deadlines; exits nonzero unless
//!                        every job succeeds and coalescing was observed
//!                        (the CI tier-1 smoke contract).

use hdpw::backend::Backend;
use hdpw::coordinator::server::handle_connection;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest, JobResult};
use hdpw::util::json::Json;
use hdpw::util::threadpool::{default_threads, Lane};
use std::io::Cursor;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Instant;

fn fail(msg: &str) -> ! {
    eprintln!("serve_load FAILED: {msg}");
    std::process::exit(1);
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Phase 1: 8 concurrent same-key jobs; returns the peak coalesced batch
/// observed (retrying with fresh keys to ride out pathological scheduling).
fn coalescing_phase() -> usize {
    let coord = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers: 8,
            max_queue: 16,
            ..CoordinatorConfig::default()
        },
    ));
    let mut base = JobRequest::default();
    base.dataset = "syn2".into();
    base.n = 4096;
    base.solver = "hdpwbatchsgd".into();
    base.max_iters = 200;
    base.batch_size = 16;
    base.time_budget = 30.0;
    base.reuse_precond = true;
    let mut peak = 0usize;
    for round in 0..5u64 {
        base.seed = 40 + round; // fresh key => fresh artifact + episode
        let (tx, rx) = mpsc::channel();
        for i in 0..8u64 {
            let mut r = base.clone();
            r.id = i;
            let tx = tx.clone();
            coord.submit(r, move |res| {
                let _ = tx.send(res);
            });
        }
        drop(tx);
        let results: Vec<JobResult> = rx
            .iter()
            .map(|r| match r {
                Ok(res) => res,
                Err(e) => fail(&format!("coalesced job errored: {e:#}")),
            })
            .collect();
        // uncoalesced reference: the same request alone on a fresh
        // coordinator — artifacts are pure functions of the key, so every
        // member of the episode must match it bit-for-bit
        let serial = Coordinator::new(Backend::native(), CoordinatorConfig::default())
            .run_job(&base)
            .unwrap_or_else(|e| fail(&format!("serial reference errored: {e:#}")));
        for r in &results {
            if r.best.x.len() != serial.best.x.len()
                || r.best
                    .x
                    .iter()
                    .zip(&serial.best.x)
                    .any(|(a, b)| a.to_bits() != b.to_bits())
                || r.best_f.to_bits() != serial.best_f.to_bits()
            {
                fail("coalesced job's trace diverged from uncoalesced execution");
            }
        }
        peak = peak.max(results.iter().map(|r| r.coalesced_batch).max().unwrap_or(1));
        println!(
            "coalescing round {round}: peak batch {} (8 concurrent same-key jobs), \
             bit-identical to serial: yes",
            peak
        );
        if peak > 1 {
            break;
        }
    }
    peak
}

/// One mixed-load request: solvers, representations, constraints, and
/// lanes cycle deterministically by index.
fn mixed_req(i: usize, with_deadlines: bool) -> JobRequest {
    let mut r = JobRequest::default();
    r.id = i as u64;
    r.dataset = "syn2".into();
    r.n = 512;
    r.max_iters = 150;
    r.batch_size = 16;
    r.time_budget = 10.0;
    r.seed = 1 + (i % 4) as u64;
    r.solver = match i % 3 {
        0 => "exact".into(),
        _ => "pwgradient".into(),
    };
    if i % 3 == 2 {
        r.constraint = "l2".into();
    }
    if i % 5 == 0 {
        r.format = "sparse".into();
        r.density = 0.2;
    }
    // 1:2:1 submission mix across high:normal:batch
    r.priority = match i % 4 {
        0 => "high",
        1 | 2 => "normal",
        _ => "batch",
    }
    .to_string();
    // full mode: some batch-lane jobs carry deadlines tight enough that a
    // loaded queue sheds them — the shed path under real load
    if with_deadlines && r.priority == "batch" && i % 8 == 7 {
        r.deadline_ms = 5.0;
    }
    r
}

fn main() {
    let smoke = std::env::var("HDPW_SERVE_SMOKE").ok().as_deref() == Some("1");
    let jobs = env_usize("HDPW_SERVE_JOBS", if smoke { 240 } else { 2000 });
    let workers = default_threads();

    println!("== phase 1: request coalescing (8 concurrent same-key jobs) ==");
    let coalesce_peak = coalescing_phase();
    if smoke && coalesce_peak < 2 {
        fail("coalesced_batch > 1 was never observed");
    }

    println!("== phase 2: mixed load ({jobs} jobs, {workers} workers) ==");
    let coord = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers,
            max_queue: 64,
            ..CoordinatorConfig::default()
        },
    ));
    let input: String = (0..jobs)
        .map(|i| mixed_req(i, !smoke).to_json().to_string() + "\n")
        .collect();
    let t0 = Instant::now();
    // serve_stdio-style: one line-delimited session over an in-memory pipe;
    // responses go to a sink (the metrics below are the measurement)
    handle_connection(&coord, Cursor::new(input), std::io::sink())
        .unwrap_or_else(|e| fail(&format!("serve session errored: {e:#}")));
    let wall = t0.elapsed().as_secs_f64();

    let m = &coord.metrics;
    let failed = m.jobs_failed.load(Ordering::Relaxed);
    let shed = m.jobs_shed.load(Ordering::Relaxed);
    let completed = m.jobs_completed.load(Ordering::Relaxed);
    let jobs_per_sec = jobs as f64 / wall.max(1e-9);
    println!(
        "{jobs} jobs in {wall:.2}s = {jobs_per_sec:.0} jobs/sec \
         (completed {completed}, shed {shed}, failed {failed}, steals {})",
        coord.pool_steals()
    );

    let lane_obj = |lane: Lane| {
        let lm = &m.lanes[lane.idx()];
        let pct = |p: f64| {
            m.lane_latency_percentile(lane, p)
                .map(|secs| secs * 1e3)
                .unwrap_or(-1.0)
        };
        println!(
            "lane {:<6}: submitted {:>4} completed {:>4} shed {:>3} \
             p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms",
            lane.name(),
            lm.submitted.load(Ordering::Relaxed),
            lm.completed.load(Ordering::Relaxed),
            lm.shed.load(Ordering::Relaxed),
            pct(50.0),
            pct(95.0),
            pct(99.0)
        );
        Json::obj(vec![
            ("submitted", Json::num(lm.submitted.load(Ordering::Relaxed) as f64)),
            ("completed", Json::num(lm.completed.load(Ordering::Relaxed) as f64)),
            ("shed", Json::num(lm.shed.load(Ordering::Relaxed) as f64)),
            ("p50_ms", Json::num(pct(50.0))),
            ("p95_ms", Json::num(pct(95.0))),
            ("p99_ms", Json::num(pct(99.0))),
        ])
    };
    let out = Json::obj(vec![
        ("jobs", Json::num(jobs as f64)),
        ("workers", Json::num(workers as f64)),
        ("wall_secs", Json::num(wall)),
        ("jobs_per_sec", Json::num(jobs_per_sec)),
        ("completed", Json::num(completed as f64)),
        ("failed", Json::num(failed as f64)),
        ("shed", Json::num(shed as f64)),
        ("coalesce_batch_max", Json::num(coalesce_peak as f64)),
        (
            "coalesced_jobs",
            Json::num(m.coalesced_jobs.load(Ordering::Relaxed) as f64),
        ),
        ("pool_steals", Json::num(coord.pool_steals() as f64)),
        ("lane_high", lane_obj(Lane::High)),
        ("lane_normal", lane_obj(Lane::Normal)),
        ("lane_batch", lane_obj(Lane::Batch)),
    ]);
    let path = "BENCH_serve.json";
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("serve load artifact: {path}"),
        Err(e) => println!("serve load artifact NOT written: {e}"),
    }

    if smoke {
        if failed > 0 {
            fail(&format!("{failed} jobs failed under the smoke load"));
        }
        if shed > 0 {
            fail(&format!("{shed} jobs shed though the smoke load sets no deadlines"));
        }
        if completed != jobs {
            fail(&format!("completed {completed} != submitted {jobs}"));
        }
        println!("smoke OK: {jobs} mixed jobs, 0 failed, coalesced_batch {coalesce_peak} > 1");
    }
}
