//! Regenerates Figure 1: HDpwBatchSGD batch-size speed-up on Syn1/Syn2.
include!("common.rs");

fn main() {
    let ctx = bench_ctx();
    let out = hdpw::experiments::fig1::run(&ctx).expect("fig1");
    for (i, fig) in out.figures.iter().enumerate() {
        println!("{}", ctx.save_and_render(fig, &format!("fig1_{i}")));
    }
    println!("{}", hdpw::experiments::fig1::render_table(&out));
}
