//! Regenerates Figure 2: Syn1 unconstrained, low- and high-precision races.
include!("common.rs");

fn main() {
    let ctx = bench_ctx();
    let panels = hdpw::experiments::fig2::run(&ctx).expect("fig2");
    println!("{}", ctx.save_and_render(&panels.low, "fig2_low"));
    println!("{}", ctx.save_and_render(&panels.high, "fig2_high"));
}
