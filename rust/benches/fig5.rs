//! Regenerates Fig5 (see experiments::figs_real).
include!("common.rs");

fn main() {
    let ctx = bench_ctx();
    let figs = hdpw::experiments::figs_real::fig5(&ctx).expect("fig5");
    for (i, fig) in figs.iter().enumerate() {
        println!("{}", ctx.save_and_render(fig, &format!("fig5_{i}")));
    }
}
