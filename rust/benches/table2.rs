//! Regenerates Table 2 (preconditioner cost per sketch + kappa(AR^-1)).
include!("common.rs");

fn main() {
    let ctx = bench_ctx();
    let out = hdpw::experiments::table2::run(&ctx).expect("table2");
    println!("{}", hdpw::experiments::table2::render(&out));
}
