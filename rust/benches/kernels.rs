//! Microbenchmarks for the native hot paths + PJRT dispatch overhead.
//! The §Perf iteration log in EXPERIMENTS.md is driven by this bench.

use hdpw::backend::Backend;
use hdpw::linalg::{blas, qr, tri, Mat};
use hdpw::constraints::Unconstrained;
use hdpw::sketch::fwht;
use hdpw::sketch::SketchKind;
use hdpw::util::rng::Rng;
use hdpw::util::stats::BenchStats;

fn main() {
    let mut rng = Rng::new(7);

    // ---- gemm -------------------------------------------------------------
    for (m, k, n) in [(256, 256, 256), (1024, 64, 64), (8192, 32, 32)] {
        let a = Mat::gaussian(m, k, &mut rng);
        let b = Mat::gaussian(k, n, &mut rng);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let st = BenchStats::run(&format!("gemm {m}x{k}x{n}"), 3, 10, || {
            std::hint::black_box(blas::gemm(&a, &b));
        });
        let gflops = flops / st.median_secs() / 1e9;
        println!("{}  [{gflops:.2} GFLOP/s]", st.report());
    }

    // ---- fused gradient (pwGradient inner step) -----------------------------
    for (n, d) in [(65_536, 32), (65_536, 96), (262_144, 32)] {
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        let x = rng.gaussians(d);
        let bytes = (n * d * 8) as f64;
        let st = BenchStats::run(&format!("fused_grad {n}x{d}"), 3, 10, || {
            std::hint::black_box(blas::fused_grad(&a, &b, &x, 2.0));
        });
        println!(
            "{}  [{:.2} GB/s effective]",
            st.report(),
            bytes / st.median_secs() / 1e9
        );
    }

    // ---- FWHT ---------------------------------------------------------------
    for (n, d) in [(65_536, 33), (262_144, 21)] {
        let a = Mat::gaussian(n, d, &mut rng);
        let bytes = (n * d * 8) as f64 * (n as f64).log2();
        let st = BenchStats::run(&format!("fwht {n}x{d}"), 2, 8, || {
            let mut m = a.clone();
            fwht::fwht_mat(&mut m);
            std::hint::black_box(m);
        });
        println!(
            "{}  [{:.2} GB/s butterfly traffic]",
            st.report(),
            bytes / st.median_secs() / 1e9
        );
    }

    // ---- simd vs native microkernels (ISSUE 6 acceptance) -------------------
    // Same inputs, same thread count; speedups land in BENCH_simd.json.
    // Acceptance: >= 2x on gemv and fwht when a real vector unit is
    // detected; ~1x is expected (and allowed) on the scalar fallback.
    {
        let threads = hdpw::util::threadpool::default_threads();
        let arch = hdpw::simd::arch();
        println!(
            "simd arch: {} ({} f64 lanes, {} threads)",
            arch.name(),
            hdpw::simd::lanes(),
            threads
        );
        let mut table: Vec<(String, f64, f64)> = Vec::new();

        // gemv at the serve shape class (tall, moderately wide)
        let (n, d) = (2048, 512);
        let a = Mat::gaussian(n, d, &mut rng);
        let x = rng.gaussians(d);
        let st_nat = BenchStats::run(&format!("gemv native {n}x{d}"), 3, 20, || {
            std::hint::black_box(blas::gemv(&a, &x));
        });
        let st_simd = BenchStats::run(&format!("gemv simd   {n}x{d}"), 3, 20, || {
            std::hint::black_box(hdpw::simd::gemv(&a, &x, threads));
        });
        println!("{}", st_nat.report());
        println!("{}", st_simd.report());
        table.push((format!("gemv {n}x{d}"), st_nat.median_secs(), st_simd.median_secs()));

        // FWHT butterfly on a 2^20 vector
        let big = rng.gaussians(1 << 20);
        let st_nat = BenchStats::run("fwht native 2^20", 2, 10, || {
            let mut v = big.clone();
            fwht::fwht_vec(&mut v);
            std::hint::black_box(v);
        });
        let st_simd = BenchStats::run("fwht simd   2^20", 2, 10, || {
            let mut v = big.clone();
            hdpw::simd::fwht_vec(&mut v);
            std::hint::black_box(v);
        });
        println!("{}", st_nat.report());
        println!("{}", st_simd.report());
        table.push(("fwht 2^20".into(), st_nat.median_secs(), st_simd.median_secs()));

        // CountSketch row-scatter fold: scalar RowOps vs the simd kernel set
        let (sn, sd, srows) = (16_384, 256, 2048);
        let sa = Mat::gaussian(sn, sd, &mut rng);
        let sk = SketchKind::CountSketch.build(srows, sn, &mut rng);
        let st_nat = BenchStats::run("countsketch scatter scalar", 2, 8, || {
            std::hint::black_box(hdpw::sketch::apply_streamed_with(
                sk.as_ref(),
                &sa,
                Some(256),
                threads,
                &hdpw::sketch::RowOps::SCALAR,
            ));
        });
        let ops = hdpw::simd::row_ops();
        let st_simd = BenchStats::run("countsketch scatter simd  ", 2, 8, || {
            std::hint::black_box(hdpw::sketch::apply_streamed_with(
                sk.as_ref(),
                &sa,
                Some(256),
                threads,
                &ops,
            ));
        });
        println!("{}", st_nat.report());
        println!("{}", st_simd.report());
        table.push((
            format!("countsketch scatter {sn}x{sd}"),
            st_nat.median_secs(),
            st_simd.median_secs(),
        ));

        // CSR mini-batch gradient (gathered row dots)
        let (cn, cd) = (65_536, 256);
        let mut srng = rng.fork(13);
        let dense = Mat::from_fn(cn, cd, |_, _| {
            if srng.uniform() < 0.05 {
                srng.gaussian()
            } else {
                0.0
            }
        });
        let csr = hdpw::linalg::CsrMat::from_dense(&dense);
        drop(dense);
        let cb = rng.gaussians(cn);
        let cx = rng.gaussians(cd);
        let tau: Vec<usize> = (0..4096).map(|_| rng.below(cn)).collect();
        let st_nat = BenchStats::run("csr batch_grad native |tau|=4096", 3, 15, || {
            std::hint::black_box(csr.batch_grad(&tau, &cb, &cx, 2.0));
        });
        let st_simd = BenchStats::run("csr batch_grad simd   |tau|=4096", 3, 15, || {
            std::hint::black_box(hdpw::simd::csr_batch_grad(&csr, &tau, &cb, &cx, 2.0));
        });
        println!("{}", st_nat.report());
        println!("{}", st_simd.report());
        table.push((
            "csr batch_grad |tau|=4096".into(),
            st_nat.median_secs(),
            st_simd.median_secs(),
        ));

        println!("simd speedup table ({}):", arch.name());
        for (name, nat, simd) in &table {
            println!(
                "  {name:32} native {:.3}ms  simd {:.3}ms  {:.2}x",
                nat * 1e3,
                simd * 1e3,
                nat / simd
            );
        }
        let rows: Vec<hdpw::util::json::Json> = table
            .iter()
            .map(|(name, nat, simd)| {
                hdpw::util::json::Json::obj(vec![
                    ("kernel", hdpw::util::json::Json::str(name.clone())),
                    ("native_secs", hdpw::util::json::Json::num(*nat)),
                    ("simd_secs", hdpw::util::json::Json::num(*simd)),
                    ("speedup", hdpw::util::json::Json::num(nat / simd)),
                ])
            })
            .collect();
        let simd_json = hdpw::util::json::Json::obj(vec![
            ("arch", hdpw::util::json::Json::str(arch.name())),
            ("lanes", hdpw::util::json::Json::num(hdpw::simd::lanes() as f64)),
            ("threads", hdpw::util::json::Json::num(threads as f64)),
            ("kernels", hdpw::util::json::Json::Arr(rows)),
        ]);
        let simd_path = "BENCH_simd.json";
        match std::fs::write(simd_path, format!("{simd_json}\n")) {
            Ok(()) => println!("simd speedup artifact: {simd_path}"),
            Err(e) => println!("simd speedup artifact NOT written: {e}"),
        }
    }

    // ---- sketch + QR (precondition setup) -----------------------------------
    for kind in [
        SketchKind::CountSketch,
        SketchKind::Srht,
        SketchKind::SparseEmbed,
    ] {
        let a = Mat::gaussian(65_536, 20, &mut rng);
        let s = hdpw::sketch::default_sketch_size_for(a.rows, a.cols, kind);
        let mut local_rng = rng.fork(3);
        let st = BenchStats::run(
            &format!("precondition {} s={s}", kind.name()),
            2,
            8,
            || {
                std::hint::black_box(hdpw::precond::precondition(&a, kind, s, &mut local_rng));
            },
        );
        println!("{}", st.report());
    }

    // ---- sparse vs dense sketch+precondition (acceptance: >= 5x) -----------
    // A 2^20 x 100 synthetic at 1% density: the CSR CountSketch pipeline
    // touches ~nnz = 2^20 stored entries where the dense pipeline streams
    // all 2^20 * 100 cells, so sketch+QR wall clock should drop >= 5x.
    {
        let n = 1 << 20;
        let d = 100;
        let s = 1000; // rotation-scale sketch keeps the shared QR cost small
        let spec = hdpw::data::sparse_gen::SparseSpec {
            name: "bench_sparse".into(),
            n,
            d,
            density: 0.01,
            kappa: 1e3,
            noise: 0.1,
            signal_scale: 1.0,
        };
        let mut gen_rng = rng.fork(41);
        let ds = hdpw::data::sparse_gen::generate_sparse(&spec, &mut gen_rng);
        let csr = ds.csr().expect("sparse dataset");
        println!(
            "sparse workload: {}x{} nnz={} density={:.4}",
            n,
            d,
            csr.nnz(),
            ds.density()
        );
        let be = Backend::native();
        // the dense comparison needs a dense view: take it through the
        // capability call on a measuring budget so the peak-bytes numbers
        // below come from the same accounting the serve path uses
        let dense_budget = hdpw::util::mem::MemBudget::unlimited();
        let dense_a = ds
            .materialize_dense(&dense_budget, "bench dense-mirror twin")
            .expect("unlimited budget");
        let mirror_bytes = dense_budget.peak();
        let mut dense_rng = rng.fork(42);
        let st_dense = BenchStats::run("precondition dense 2^20x100 countsketch", 1, 3, || {
            std::hint::black_box(hdpw::precond::precondition_with(
                &be,
                dense_a,
                SketchKind::CountSketch,
                s,
                &mut dense_rng,
                None,
            ));
        });
        println!("{}", st_dense.report());
        let mut csr_rng = rng.fork(42);
        let st_csr = BenchStats::run("precondition csr   2^20x100 countsketch", 1, 3, || {
            std::hint::black_box(hdpw::precond::precondition_csr_with(
                &be,
                csr,
                SketchKind::CountSketch,
                s,
                &mut csr_rng,
                None,
            ));
        });
        println!("{}", st_csr.report());
        println!(
            "sparse sketch+precondition speedup: {:.1}x (acceptance: >= 5x)",
            st_dense.median_secs() / st_csr.median_secs()
        );

        // ---- peak tracked bytes: dense-mirror invariant vs lazy design ----
        // The pre-refactor Dataset invariant forced `mirror_bytes` of dense
        // RAM the moment a CSR dataset was loaded. The lazy DesignMatrix
        // charges 0 bytes on the step-1-only path; the HD path charges one
        // padded [A | b] buffer. Acceptance: lazy step-1 peak < 0.5x the
        // mirror footprint (it is exactly 0).
        let lazy = hdpw::data::Dataset::from_csr("bench_lazy", csr.clone(), ds.b.clone(), None);
        let step1_budget = hdpw::util::mem::MemBudget::unlimited();
        {
            // the BUDGETED entry point: any tracked densification on the
            // step-1 path charges (and fails the acceptance line) here
            let mut r = rng.fork(43);
            std::hint::black_box(
                hdpw::precond::precondition_ds_budgeted(
                    &be,
                    &lazy,
                    SketchKind::CountSketch,
                    s,
                    &mut r,
                    None,
                    &step1_budget,
                )
                .expect("unlimited budget"),
            );
        }
        let step1_peak = step1_budget.peak();
        assert!(
            lazy.dense_if_ready().is_none(),
            "step-1 sketch must not materialize a mirror"
        );
        let hd_budget = hdpw::util::mem::MemBudget::unlimited();
        let hd_peak = {
            let mut r = rng.fork(44);
            let hd = hdpw::precond::hd_transform_ds_with(&be, &lazy, &mut r, &hd_budget, "bench hd")
                .expect("unlimited budget");
            let peak = hd_budget.peak();
            drop(hd);
            peak
        };
        println!(
            "peak tracked bytes: dense-mirror={mirror_bytes} lazy-step1={step1_peak} \
             lazy-hd={hd_peak} (acceptance: lazy-step1 < 0.5x mirror)"
        );
        println!(
            "mem acceptance: {}",
            if (step1_peak as f64) < 0.5 * mirror_bytes as f64 {
                "PASS"
            } else {
                "FAIL"
            }
        );
        let mem_json = hdpw::util::json::Json::obj(vec![
            ("workload", hdpw::util::json::Json::str(format!("{n}x{d}@0.01"))),
            ("nnz", hdpw::util::json::Json::num(csr.nnz() as f64)),
            (
                "dense_mirror_bytes",
                hdpw::util::json::Json::num(mirror_bytes as f64),
            ),
            (
                "lazy_step1_peak_bytes",
                hdpw::util::json::Json::num(step1_peak as f64),
            ),
            (
                "lazy_hd_peak_bytes",
                hdpw::util::json::Json::num(hd_peak as f64),
            ),
            (
                "densify_events_step1",
                hdpw::util::json::Json::num(step1_budget.densify_events() as f64),
            ),
            (
                "speedup",
                hdpw::util::json::Json::num(st_dense.median_secs() / st_csr.median_secs()),
            ),
        ]);
        let mem_path = "BENCH_mem.json";
        match std::fs::write(mem_path, format!("{mem_json}\n")) {
            Ok(()) => println!("mem trajectory artifact: {mem_path}"),
            Err(e) => println!("mem trajectory artifact NOT written: {e}"),
        }

        // ---- sparse IHS step 2: dense-HD materialize vs implicit gather ----
        // The HD solver family no longer materializes the padded [A | b]
        // buffer on CSR inputs: sampled rows of HD[A|b] are evaluated on
        // demand from the CSR payload in O(nnz + n) each. Both sides of the
        // flops-for-memory trade priced at the same workload — the one-time
        // buffer + full FWHT (n_pad*(d+1)*8 bytes resident) against the
        // per-batch implicit gather — plus the break-even batch count where
        // amortizing the FWHT would win on wall clock alone.
        let n_pad = hdpw::linalg::matrix::next_pow2(n);
        let ihs_buffer_bytes = hdpw::precond::hd_buffer_bytes(n, d);
        let mut dense_hd_rng = rng.fork(45);
        let st_hd_dense = BenchStats::run("ihs step2 dense-hd buffer+fwht 2^20x100", 1, 2, || {
            let budget = hdpw::util::mem::MemBudget::unlimited();
            let mut r = dense_hd_rng.fork(1);
            std::hint::black_box(
                hdpw::precond::hd_transform_ds_with(
                    &be,
                    &lazy,
                    &mut r,
                    &budget,
                    "bench ihs dense-hd",
                )
                .expect("unlimited budget"),
            );
        });
        println!("{}", st_hd_dense.report());
        let mut imp_rng = rng.fork(46);
        let st_imp_setup = BenchStats::run("ihs step2 implicit setup (signs only)", 2, 8, || {
            let mut r = imp_rng.fork(1);
            std::hint::black_box(hdpw::precond::hd_implicit_ds(&lazy, &mut r));
        });
        println!("{}", st_imp_setup.report());
        // one materialized transform + one implicit handle drawn from the
        // same rng stream position, for the gather timings and a row-level
        // parity check (the replay-parity contract the solvers rely on)
        let hd = {
            let budget = hdpw::util::mem::MemBudget::unlimited();
            let mut r = rng.fork(47);
            hdpw::precond::hd_transform_ds_with(&be, &lazy, &mut r, &budget, "bench ihs parity")
                .expect("unlimited budget")
        };
        let ihd = {
            let mut r = rng.fork(47);
            hdpw::precond::hd_implicit_ds(&lazy, &mut r)
        };
        let batch_r = 256usize;
        let mut idx_rng = rng.fork(48);
        let idx: Vec<usize> = (0..batch_r).map(|_| idx_rng.below(n_pad)).collect();
        let st_imp_gather = BenchStats::run("ihs step2 implicit gather r=256", 1, 3, || {
            std::hint::black_box(ihd.gather_rows_csr(csr, &lazy.b, &idx));
        });
        println!("{}", st_imp_gather.report());
        let st_dense_gather = BenchStats::run("ihs step2 dense    gather r=256", 2, 8, || {
            let rows = hd.hda.gather_rows(&idx);
            let rhs: Vec<f64> = idx.iter().map(|&i| hd.hdb[i]).collect();
            std::hint::black_box((rows, rhs));
        });
        println!("{}", st_dense_gather.report());
        let (ga, gb) = ihd.gather_rows_csr(csr, &lazy.b, &idx);
        let da = hd.hda.gather_rows(&idx);
        let mut parity = ga.max_abs_diff(&da);
        for (i, &src) in idx.iter().enumerate() {
            parity = parity.max((gb[i] - hd.hdb[src]).abs());
        }
        assert!(parity < 1e-9, "implicit/dense HD row parity: {parity}");
        // break-even: #batches at which (dense one-time cost + cheap dense
        // gathers) catches up with paying the implicit gather every batch
        let per_gather_gap =
            (st_imp_gather.median_secs() - st_dense_gather.median_secs()).max(1e-12);
        let break_even = st_hd_dense.median_secs() / per_gather_gap;
        println!(
            "ihs step2 trade: buffer={ihs_buffer_bytes} bytes held vs 0; \
             break-even ~{break_even:.0} gathers of r={batch_r} \
             (parity {parity:.2e})"
        );
        let ihs_json = hdpw::util::json::Json::obj(vec![
            ("workload", hdpw::util::json::Json::str(format!("{n}x{d}@0.01"))),
            ("n_pad", hdpw::util::json::Json::num(n_pad as f64)),
            ("nnz", hdpw::util::json::Json::num(csr.nnz() as f64)),
            (
                "hd_buffer_bytes",
                hdpw::util::json::Json::num(ihs_buffer_bytes as f64),
            ),
            (
                "dense_hd_secs",
                hdpw::util::json::Json::num(st_hd_dense.median_secs()),
            ),
            (
                "implicit_setup_secs",
                hdpw::util::json::Json::num(st_imp_setup.median_secs()),
            ),
            ("batch_r", hdpw::util::json::Json::num(batch_r as f64)),
            (
                "implicit_gather_secs",
                hdpw::util::json::Json::num(st_imp_gather.median_secs()),
            ),
            (
                "dense_gather_secs",
                hdpw::util::json::Json::num(st_dense_gather.median_secs()),
            ),
            (
                "break_even_batches",
                hdpw::util::json::Json::num(break_even),
            ),
            ("gather_parity_max_diff", hdpw::util::json::Json::num(parity)),
        ]);
        let ihs_path = "BENCH_ihs_sparse.json";
        match std::fs::write(ihs_path, format!("{ihs_json}\n")) {
            Ok(()) => println!("sparse-IHS trade artifact: {ihs_path}"),
            Err(e) => println!("sparse-IHS trade artifact NOT written: {e}"),
        }

        // ---- batched gather: blockwise vs per-row (ISSUE 9 acceptance) ----
        // Same implicit handle, same sampled index set. The per-row
        // reference re-walks the CSR payload once per sampled row (r passes
        // over nnz); the blockwise gather hoists the sign-panel coefficients
        // and walks the payload once per batch, scattering each stored entry
        // into every sampled row. Acceptance: >= 4x at 2^20 x 100 @ 1%
        // density, r = 256. Outputs are bitwise equal by construction
        // (asserted below), so the speedup is free of numerics caveats.
        let st_gather_ref = BenchStats::run("hd gather per-row   r=256", 1, 2, || {
            std::hint::black_box(ihd.gather_rows_csr_ref(csr, &lazy.b, &idx));
        });
        println!("{}", st_gather_ref.report());
        let st_gather_blk = BenchStats::run("hd gather blockwise r=256", 1, 3, || {
            std::hint::black_box(ihd.gather_rows_csr(csr, &lazy.b, &idx));
        });
        println!("{}", st_gather_blk.report());
        let (blk_a, blk_b) = ihd.gather_rows_csr(csr, &lazy.b, &idx);
        let (ref_a, ref_b) = ihd.gather_rows_csr_ref(csr, &lazy.b, &idx);
        assert!(
            blk_a.max_abs_diff(&ref_a) == 0.0
                && blk_b
                    .iter()
                    .zip(&ref_b)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
            "blockwise gather must be bitwise equal to the per-row reference"
        );
        let gather_speedup = st_gather_ref.median_secs() / st_gather_blk.median_secs();
        println!(
            "blockwise gather speedup: {gather_speedup:.1}x (acceptance: >= 4x)"
        );

        // ---- fused trials vs serial drive (trial throughput) --------------
        // The cross-trial GEMM fusion: k trials advance in lockstep sharing
        // one fused objective pass per chunk boundary instead of k separate
        // residual sweeps. Reports are bitwise equal to the serial drive
        // (asserted below; tests/implicit_gather.rs is the full gate) — the
        // fusion only buys wall clock.
        let fn_rows = 8192;
        let fd = 32;
        let fa = Mat::gaussian(fn_rows, fd, &mut rng);
        let fb = rng.gaussians(fn_rows);
        let fds = hdpw::data::Dataset::dense("bench_fused", fa, fb, None);
        let solver = hdpw::solvers::by_name("hdpwbatchsgd").expect("registered solver");
        let k_trials = 4usize;
        let opts_list: Vec<hdpw::solvers::SolverOpts> = (0..k_trials)
            .map(|t| hdpw::solvers::SolverOpts {
                batch_size: 64,
                max_iters: 2000,
                chunk: 100,
                time_budget: 1e9,
                seed: 90 + t as u64,
                ..Default::default()
            })
            .collect();
        let st_serial = BenchStats::run(
            &format!("trials serial {k_trials}x hdpwbatchsgd {fn_rows}x{fd}"),
            1,
            3,
            || {
                for o in &opts_list {
                    std::hint::black_box(
                        solver.solve(&be, &fds, o).expect("serial solve"),
                    );
                }
            },
        );
        println!("{}", st_serial.report());
        let st_fused = BenchStats::run(
            &format!("trials fused  {k_trials}x hdpwbatchsgd {fn_rows}x{fd}"),
            1,
            3,
            || {
                std::hint::black_box(
                    hdpw::solvers::drive_fused_trials(solver.as_ref(), &be, &fds, &opts_list)
                        .expect("fused solve"),
                );
            },
        );
        println!("{}", st_fused.report());
        let fused_reports =
            hdpw::solvers::drive_fused_trials(solver.as_ref(), &be, &fds, &opts_list)
                .expect("fused solve");
        for (o, fr) in opts_list.iter().zip(&fused_reports) {
            let sr = solver.solve(&be, &fds, o).expect("serial solve");
            assert!(
                fr.f_final.to_bits() == sr.f_final.to_bits()
                    && fr.x.iter().zip(&sr.x).all(|(x, y)| x.to_bits() == y.to_bits()),
                "fused trial (seed {}) must be bitwise equal to serial",
                o.seed
            );
        }
        let serial_jps = k_trials as f64 / st_serial.median_secs();
        let fused_jps = k_trials as f64 / st_fused.median_secs();
        println!(
            "fused trial throughput: serial {serial_jps:.2} trials/s, \
             fused {fused_jps:.2} trials/s ({:.2}x, bitwise-equal reports)",
            fused_jps / serial_jps
        );

        let gather_json = hdpw::util::json::Json::obj(vec![
            ("workload", hdpw::util::json::Json::str(format!("{n}x{d}@0.01"))),
            ("batch_r", hdpw::util::json::Json::num(batch_r as f64)),
            (
                "per_row_gather_secs",
                hdpw::util::json::Json::num(st_gather_ref.median_secs()),
            ),
            (
                "blockwise_gather_secs",
                hdpw::util::json::Json::num(st_gather_blk.median_secs()),
            ),
            (
                "gather_speedup",
                hdpw::util::json::Json::num(gather_speedup),
            ),
            (
                "fused_workload",
                hdpw::util::json::Json::str(format!(
                    "hdpwbatchsgd {fn_rows}x{fd} k={k_trials}"
                )),
            ),
            (
                "serial_trials_per_sec",
                hdpw::util::json::Json::num(serial_jps),
            ),
            (
                "fused_trials_per_sec",
                hdpw::util::json::Json::num(fused_jps),
            ),
            (
                "fused_throughput_ratio",
                hdpw::util::json::Json::num(fused_jps / serial_jps),
            ),
        ]);
        let gather_path = "BENCH_gather.json";
        match std::fs::write(gather_path, format!("{gather_json}\n")) {
            Ok(()) => println!("batched hot-path artifact: {gather_path}"),
            Err(e) => println!("batched hot-path artifact NOT written: {e}"),
        }
    }

    // ---- QR + triangular ------------------------------------------------------
    let sa = Mat::gaussian(1000, 20, &mut rng);
    let st = BenchStats::run("qr_r 1000x20", 3, 20, || {
        std::hint::black_box(qr::qr_r(&sa));
    });
    println!("{}", st.report());
    let r = qr::qr_r(&sa);
    let g = rng.gaussians(20);
    let st = BenchStats::run("apply_pinv d=20", 5, 50, || {
        std::hint::black_box(tri::apply_pinv(&r, &g));
    });
    println!("{}", st.report());

    // ---- native sgd_chunk (solver inner loop) ----------------------------------
    let n = 65_536;
    let d = 32;
    let hda = Mat::gaussian(n, d, &mut rng);
    let hdb = rng.gaussians(n);
    let pinv = Mat::eye(d);
    let x0 = rng.gaussians(d);
    for r in [16usize, 256] {
        let idx: Vec<Vec<usize>> = (0..50).map(|_| rng.indices(r, n)).collect();
        let be = Backend::native();
        let st = BenchStats::run(&format!("sgd_chunk native r={r} T=50"), 2, 10, || {
            std::hint::black_box(be.sgd_chunk(
                &hda,
                &hdb,
                &x0,
                &pinv,
                &idx,
                0.1,
                2.0 * n as f64 / r as f64,
                &Unconstrained,
                None,
            ));
        });
        println!(
            "{}  [{:.1}us/iter]",
            st.report(),
            st.median_secs() / 50.0 * 1e6
        );
    }

    // ---- PJRT dispatch overhead (artifact shapes) -------------------------------
    let auto = Backend::auto();
    if auto.has_pjrt() {
        let n = 8192;
        let d = 32;
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        let x = rng.gaussians(d);
        let st = BenchStats::run("pjrt full_grad 8192x32", 3, 20, || {
            std::hint::black_box(auto.full_grad(&a, &b, &x));
        });
        println!("{}", st.report());
        let nat = Backend::native();
        let st2 = BenchStats::run("native full_grad 8192x32", 3, 20, || {
            std::hint::black_box(nat.full_grad(&a, &b, &x));
        });
        println!("{}", st2.report());
        let idx: Vec<Vec<usize>> = (0..50).map(|_| rng.indices(64, n)).collect();
        let pinv = Mat::eye(d);
        let st3 = BenchStats::run("pjrt sgd_chunk r=64 T=50", 2, 10, || {
            std::hint::black_box(auto.sgd_chunk(
                &a,
                &b,
                &x,
                &pinv,
                &idx,
                0.1,
                2.0 * n as f64 / 64.0,
                &Unconstrained,
                None,
            ));
        });
        println!(
            "{}  [{:.1}us/iter]",
            st3.report(),
            st3.median_secs() / 50.0 * 1e6
        );
        let st4 = BenchStats::run("native sgd_chunk r=64 T=50 (8192x32)", 2, 10, || {
            std::hint::black_box(nat.sgd_chunk(
                &a,
                &b,
                &x,
                &pinv,
                &idx,
                0.1,
                2.0 * n as f64 / 64.0,
                &Unconstrained,
                None,
            ));
        });
        println!(
            "{}  [{:.1}us/iter]",
            st4.report(),
            st4.median_secs() / 50.0 * 1e6
        );
    } else {
        println!("(PJRT artifacts not found: run `make artifacts` for dispatch benches)");
    }
}
