//! Reference-implementation coverage for the projection layer:
//! * l1-ball and simplex projections checked against O(d^2) brute-force
//!   dual searches;
//! * elastic-net projection checked against its KKT conditions, its l1/l2
//!   degenerate cases, and random feasible candidates;
//! * per-coordinate box and affine-equality projections checked against
//!   independent dense references (<= 1e-10);
//! * box constraint edge cases (lo == hi, no violation);
//! * R-metric projection consistency with the Euclidean path when R = I,
//!   for the legacy sets AND every new set (the ADMM fallback must collapse
//!   to a single Euclidean projection at H = I).

use hdpw::constraints::{
    affine_eq, coord_box, elastic_net, nonneg, simplex, AffineEquality, ConstraintSet, CoordBox,
    L1Ball, L2Ball, ScalarBox, Unconstrained,
};
use hdpw::linalg::{blas, qr, Mat};
use hdpw::prox::metric::MetricProjector;
use hdpw::prox::{
    elastic_net_value, project_elastic_net, project_l1, project_l2, project_simplex,
};
use hdpw::Rng;

/// O(d^2) reference for the Euclidean l1-ball projection: for each support
/// size k over the magnitudes sorted descending, compute the candidate
/// threshold theta_k = (sum of top-k - radius) / k and keep the one whose
/// soft-threshold lands exactly on the ball boundary. No pivot tricks —
/// just the KKT conditions checked exhaustively.
fn brute_force_l1(x: &[f64], radius: f64) -> Vec<f64> {
    let l1: f64 = x.iter().map(|v| v.abs()).sum();
    if l1 <= radius {
        return x.to_vec();
    }
    let mut mags: Vec<f64> = x.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let d = mags.len();
    let mut best_theta = 0.0;
    for k in 1..=d {
        // O(d) prefix sum per candidate k => O(d^2) total, by design
        let prefix: f64 = mags[..k].iter().sum();
        let theta = (prefix - radius) / k as f64;
        // valid iff every kept coordinate stays positive after shrinking
        // and every dropped coordinate would not survive
        let kept_ok = mags[k - 1] - theta > 0.0;
        let dropped_ok = k == d || mags[k] - theta <= 0.0;
        if kept_ok && dropped_ok {
            best_theta = theta;
        }
    }
    x.iter()
        .map(|v| v.signum() * (v.abs() - best_theta).max(0.0))
        .collect()
}

/// O(d^2) reference for the simplex projection: scan every support size k
/// over the coordinates sorted descending, compute the candidate shift
/// theta_k = (sum of top-k - total) / k, and keep the k whose KKT
/// conditions hold (kept coordinates stay positive, dropped ones would
/// not).
fn brute_force_simplex(x: &[f64], total: f64) -> Vec<f64> {
    let mut sorted: Vec<f64> = x.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let d = sorted.len();
    let mut best_theta = f64::NEG_INFINITY;
    for k in 1..=d {
        let prefix: f64 = sorted[..k].iter().sum();
        let theta = (prefix - total) / k as f64;
        let kept_ok = sorted[k - 1] - theta > 0.0;
        let dropped_ok = k == d || sorted[k] - theta <= 0.0;
        if kept_ok && dropped_ok {
            best_theta = theta;
        }
    }
    x.iter().map(|v| (v - best_theta).max(0.0)).collect()
}

#[test]
fn l1_projection_matches_brute_force_reference() {
    let mut rng = Rng::new(1);
    for trial in 0..200 {
        let d = 2 + (trial % 30);
        let mut x: Vec<f64> = rng.gaussians(d).iter().map(|v| v * 3.0).collect();
        let radius = 0.1 + rng.uniform() * 4.0;
        let reference = brute_force_l1(&x, radius);
        project_l1(&mut x, radius);
        for (a, b) in x.iter().zip(&reference) {
            assert!(
                (a - b).abs() < 1e-10,
                "trial {trial}: pivot {a} vs brute force {b}"
            );
        }
    }
}

#[test]
fn l1_projection_brute_force_on_adversarial_shapes() {
    // ties, zeros, one dominant coordinate, all-equal magnitudes
    let cases: Vec<(Vec<f64>, f64)> = vec![
        (vec![1.0, 1.0, 1.0, 1.0], 2.0),
        (vec![5.0, 0.0, 0.0], 1.0),
        (vec![-2.0, 2.0, -2.0, 2.0], 3.0),
        (vec![1e-12, 1.0, -1e-12], 0.5),
        (vec![3.0, -0.1, 1.0, -3.0], 2.0),
    ];
    for (x0, radius) in cases {
        let reference = brute_force_l1(&x0, radius);
        let mut x = x0.clone();
        project_l1(&mut x, radius);
        let l1: f64 = x.iter().map(|v| v.abs()).sum();
        assert!(l1 <= radius + 1e-9, "{x0:?}: left the ball ({l1})");
        for (a, b) in x.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-10, "{x0:?}: {a} vs {b}");
        }
    }
}

#[test]
fn simplex_projection_matches_brute_force_reference() {
    let mut rng = Rng::new(2);
    for trial in 0..200 {
        let d = 2 + (trial % 25);
        let total = 0.5 + rng.uniform() * 2.0;
        let mut x: Vec<f64> = rng.gaussians(d).iter().map(|v| v * 2.0).collect();
        let reference = brute_force_simplex(&x, total);
        project_simplex(&mut x, total);
        for (a, b) in x.iter().zip(&reference) {
            assert!(
                (a - b).abs() < 1e-10,
                "trial {trial}: pivot {a} vs brute force {b}"
            );
        }
        // KKT spot checks: feasibility + the active-set shift is uniform
        let sum: f64 = x.iter().sum();
        assert!((sum - total).abs() < 1e-10);
        assert!(x.iter().all(|&v| v >= 0.0));
    }
    // adversarial: ties, already-feasible, one dominant coordinate
    for (x0, total) in [
        (vec![0.5, 0.5, 0.5, 0.5], 1.0),
        (vec![0.25, 0.25, 0.5], 1.0),
        (vec![10.0, 0.0, 0.0], 1.0),
        (vec![-1.0, -2.0, -3.0], 1.0),
    ] {
        let reference = brute_force_simplex(&x0, total);
        let mut x = x0.clone();
        project_simplex(&mut x, total);
        for (a, b) in x.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-10, "{x0:?}: {a} vs {b}");
        }
    }
}

#[test]
fn elastic_net_projection_satisfies_kkt_and_degenerate_references() {
    let mut rng = Rng::new(3);
    for trial in 0..100 {
        let d = 2 + (trial % 12);
        let x0: Vec<f64> = rng.gaussians(d).iter().map(|v| v * 3.0).collect();
        let alpha = rng.uniform();
        let radius = 0.2 + rng.uniform();
        if elastic_net_value(&x0, alpha) <= radius {
            continue;
        }
        let mut y = x0.clone();
        project_elastic_net(&mut y, alpha, radius);
        // KKT (primal feasibility, boundary): the active constraint binds
        let g = elastic_net_value(&y, alpha);
        assert!((g - radius).abs() < 1e-10, "trial {trial}: g {g} vs r {radius}");
        // KKT (stationarity): recover nu from any strictly nonzero
        // coordinate, then every coordinate must satisfy
        //   y_i (1 + nu (1-alpha)) = sign(y_i) max(|x_i| - nu alpha, 0)
        let nu = y
            .iter()
            .zip(&x0)
            .filter(|(yi, _)| yi.abs() > 1e-8)
            .map(|(yi, xi)| {
                // |x_i| - |y_i| = nu (alpha + (1-alpha) |y_i|)
                (xi.abs() - yi.abs()) / (alpha + (1.0 - alpha) * yi.abs())
            })
            .next()
            .expect("projection of an infeasible point is nonzero");
        assert!(nu > 0.0, "trial {trial}: multiplier must be positive");
        for (yi, xi) in y.iter().zip(&x0) {
            let want = xi.signum() * (xi.abs() - nu * alpha).max(0.0)
                / (1.0 + nu * (1.0 - alpha));
            assert!(
                (yi - want).abs() < 1e-8 * (1.0 + want.abs()),
                "trial {trial}: stationarity {yi} vs {want}"
            );
        }
        // Euclidean optimality vs random feasible candidates
        let dy: f64 = x0.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
        for _ in 0..200 {
            let mut c = rng.gaussians(d);
            // rescale until feasible (value is increasing in scale)
            for _ in 0..60 {
                if elastic_net_value(&c, alpha) <= radius {
                    break;
                }
                for v in &mut c {
                    *v *= 0.8;
                }
            }
            let dc: f64 = x0.iter().zip(&c).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(dc >= dy - 1e-9, "candidate beats projection");
        }
    }
    // degenerate references: alpha = 1 is the (brute-forced) l1 ball,
    // alpha = 0 the l2 ball of radius sqrt(2 r)
    let x0: Vec<f64> = Rng::new(4).gaussians(9).iter().map(|v| v * 3.0).collect();
    let mut e1 = x0.clone();
    project_elastic_net(&mut e1, 1.0, 1.2);
    for (a, b) in e1.iter().zip(&brute_force_l1(&x0, 1.2)) {
        assert!((a - b).abs() < 1e-9, "alpha=1: {a} vs {b}");
    }
    let mut e0 = x0.clone();
    project_elastic_net(&mut e0, 0.0, 1.0);
    let mut l2 = x0.clone();
    project_l2(&mut l2, 2f64.sqrt());
    for (a, b) in e0.iter().zip(&l2) {
        assert!((a - b).abs() < 1e-9, "alpha=0: {a} vs {b}");
    }
}

#[test]
fn coord_box_projection_matches_per_coordinate_reference() {
    let mut rng = Rng::new(5);
    for _ in 0..100 {
        let d = 2 + (rng.below(12));
        let lo: Vec<f64> = (0..d).map(|_| -1.5 + rng.uniform()).collect();
        let hi: Vec<f64> = lo.iter().map(|&l| l + rng.uniform() * 2.0).collect();
        let x0: Vec<f64> = rng.gaussians(d).iter().map(|v| v * 3.0).collect();
        // independent reference: per-coordinate 1-D minimization over the
        // three candidates {lo_i, hi_i, x_i-if-inside}
        let reference: Vec<f64> = (0..d)
            .map(|i| {
                let cands = [lo[i], hi[i], x0[i].clamp(lo[i], hi[i])];
                *cands
                    .iter()
                    .min_by(|a, b| {
                        let da = (x0[i] - **a).abs();
                        let db = (x0[i] - **b).abs();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap()
            })
            .collect();
        let set = CoordBox {
            lo: lo.clone(),
            hi: hi.clone(),
        };
        let mut x = x0.clone();
        set.project(&mut x);
        for (a, b) in x.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        assert!(set.contains(&x, 1e-12));
    }
}

#[test]
fn affine_projection_matches_dense_normal_equations_reference() {
    let mut rng = Rng::new(6);
    for trial in 0..50 {
        let d = 3 + trial % 8;
        let k = 1 + trial % d.min(3);
        let c = Mat::gaussian(k, d, &mut rng);
        let e = rng.gaussians(k);
        let set = AffineEquality::new(c.clone(), e.clone()).unwrap();
        let x0 = rng.gaussians(d);
        // independent reference: x - C^T (C C^T)^{-1} (C x - e) with the
        // k x k system solved by dense QR
        let cct = blas::gemm(&c, &c.transpose());
        let mut rhs = vec![0.0; k];
        for i in 0..k {
            rhs[i] = blas::dot(c.row(i), &x0) - e[i];
        }
        let lam = qr::lstsq(&cct, &rhs);
        let mut reference = x0.clone();
        for i in 0..k {
            for j in 0..d {
                reference[j] -= c.at(i, j) * lam[i];
            }
        }
        let mut x = x0.clone();
        set.project(&mut x);
        let scale = 1.0 + blas::nrm2(&reference);
        for (a, b) in x.iter().zip(&reference) {
            assert!(
                (a - b).abs() < 1e-10 * scale,
                "trial {trial}: {a} vs {b}"
            );
        }
        // KKT spot check: residual feasibility + displacement in range(C^T)
        assert!(set.contains(&x, 1e-9 * (1.0 + blas::nrm2(&e))));
    }
}

#[test]
fn box_degenerate_lo_equals_hi_pins_every_coordinate() {
    let c = ScalarBox { lo: 0.7, hi: 0.7 };
    let mut x = vec![-3.0, 0.7, 12.0, 0.0];
    c.project(&mut x);
    assert_eq!(x, vec![0.7; 4]);
    assert!(c.contains(&x, 1e-12));
    // idempotent on the degenerate box too
    c.project(&mut x);
    assert_eq!(x, vec![0.7; 4]);
}

#[test]
fn box_with_no_violation_is_identity() {
    let c = ScalarBox { lo: -1.0, hi: 1.0 };
    let inside = vec![0.3, -0.9999, 0.0, 1.0, -1.0];
    let mut x = inside.clone();
    c.project(&mut x);
    assert_eq!(x, inside, "interior/boundary points must be untouched");
    assert!(c.contains(&x, 0.0));
}

#[test]
fn metric_projection_with_identity_r_matches_euclidean_l2_and_l1() {
    // H = R^T R = I: the quadratic subproblem degenerates to the Euclidean
    // projection; the metric path must agree with the direct one.
    let mut rng = Rng::new(7);
    let proj = MetricProjector::from_r(&Mat::eye(9));
    for _ in 0..20 {
        let z: Vec<f64> = rng.gaussians(9).iter().map(|v| v * 4.0).collect();
        // l2
        let got = proj.project(&z, &L2Ball { radius: 1.3 });
        let mut want = z.clone();
        project_l2(&mut want, 1.3);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8, "l2: {a} vs {b}");
        }
        // l1 (ADMM path) — also cross-checked against the brute force
        let got = proj.project(&z, &L1Ball { radius: 2.0 });
        let want = brute_force_l1(&z, 2.0);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "l1: {a} vs {b}");
        }
    }
}

#[test]
fn metric_projection_with_identity_r_matches_euclidean_box() {
    let mut rng = Rng::new(9);
    let proj = MetricProjector::from_r(&Mat::eye(6));
    let cons = ScalarBox { lo: -0.5, hi: 0.25 };
    for _ in 0..20 {
        let z: Vec<f64> = rng.gaussians(6).iter().map(|v| v * 2.0).collect();
        let got = proj.project(&z, &cons);
        let mut want = z.clone();
        cons.project(&mut want);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "box: {a} vs {b}");
        }
        assert!(cons.contains(&got, 1e-9));
    }
}

#[test]
fn metric_fallback_with_identity_r_collapses_for_every_new_set() {
    // the documented ADMM fallback contract: at H = I the metric
    // projection of every new set reduces to its Euclidean projection
    let mut rng = Rng::new(10);
    let proj = MetricProjector::from_r(&Mat::eye(6));
    let sets: Vec<hdpw::ConstraintRef> = vec![
        simplex(1.0),
        nonneg(),
        coord_box(vec![-0.4; 6], vec![0.6; 6]),
        elastic_net(0.5, 0.8),
        affine_eq(Mat::from_fn(1, 6, |_, _| 1.0), vec![0.5]).unwrap(),
    ];
    for set in &sets {
        for _ in 0..10 {
            let z: Vec<f64> = rng.gaussians(6).iter().map(|v| v * 2.0).collect();
            let got = proj.project(&z, set.as_ref());
            let mut want = z.clone();
            set.project(&mut want);
            let tol = if set.tag() == "affine" { 1e-8 } else { 1e-6 };
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < tol, "{}: {a} vs {b}", set.tag());
            }
            assert!(set.contains(&got, 1e-6), "{} infeasible", set.tag());
        }
    }
}

#[test]
fn metric_projection_respects_an_ill_conditioned_metric_for_new_sets() {
    // a genuinely anisotropic H: the metric projection must beat the
    // Euclidean projection in H-distance whenever they differ
    let mut rng = Rng::new(11);
    let a = Mat::from_fn(80, 6, |_i, j| rng.gaussian() * 10f64.powi(j as i32 - 3));
    let r = qr::qr_r(&a);
    let h = blas::gemm(&r.transpose(), &r);
    let proj = MetricProjector::from_r(&r);
    let h_dist = |u: &[f64], v: &[f64]| {
        let dxy = blas::sub(u, v);
        blas::dot(&dxy, &blas::gemv(&h, &dxy))
    };
    let sets: Vec<hdpw::ConstraintRef> = vec![simplex(1.0), elastic_net(0.5, 0.4)];
    for set in &sets {
        for _ in 0..10 {
            let z: Vec<f64> = rng.gaussians(6).iter().map(|v| v * 3.0).collect();
            let metric_proj = proj.project(&z, set.as_ref());
            assert!(set.contains(&metric_proj, 1e-6), "{}", set.tag());
            let mut euclid = z.clone();
            set.project(&mut euclid);
            // metric projection minimizes H-distance among feasible points
            assert!(
                h_dist(&z, &metric_proj) <= h_dist(&z, &euclid) + 1e-6,
                "{}: metric {} vs euclid {}",
                set.tag(),
                h_dist(&z, &metric_proj),
                h_dist(&z, &euclid)
            );
        }
    }
}

#[test]
fn metric_projection_unconstrained_is_identity() {
    let mut rng = Rng::new(11);
    let a = Mat::gaussian(40, 5, &mut rng);
    let r = qr::qr_r(&a);
    let proj = MetricProjector::from_r(&r);
    let z: Vec<f64> = rng.gaussians(5);
    let got = proj.project(&z, &Unconstrained);
    assert_eq!(got, z);
}
