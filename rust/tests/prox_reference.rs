//! Reference-implementation coverage for `prox/`:
//! * l1-ball projection checked against an O(d^2) brute-force dual search;
//! * box constraint edge cases (lo == hi, no violation);
//! * R-metric projection consistency with the Euclidean path when R = I.

use hdpw::linalg::Mat;
use hdpw::prox::metric::MetricProjector;
use hdpw::prox::{project_l1, project_l2, Constraint};
use hdpw::Rng;

/// O(d^2) reference for the Euclidean l1-ball projection: for each support
/// size k over the magnitudes sorted descending, compute the candidate
/// threshold theta_k = (sum of top-k - radius) / k and keep the one whose
/// soft-threshold lands exactly on the ball boundary. No pivot tricks —
/// just the KKT conditions checked exhaustively.
fn brute_force_l1(x: &[f64], radius: f64) -> Vec<f64> {
    let l1: f64 = x.iter().map(|v| v.abs()).sum();
    if l1 <= radius {
        return x.to_vec();
    }
    let mut mags: Vec<f64> = x.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let d = mags.len();
    let mut best_theta = 0.0;
    for k in 1..=d {
        // O(d) prefix sum per candidate k => O(d^2) total, by design
        let prefix: f64 = mags[..k].iter().sum();
        let theta = (prefix - radius) / k as f64;
        // valid iff every kept coordinate stays positive after shrinking
        // and every dropped coordinate would not survive
        let kept_ok = mags[k - 1] - theta > 0.0;
        let dropped_ok = k == d || mags[k] - theta <= 0.0;
        if kept_ok && dropped_ok {
            best_theta = theta;
        }
    }
    x.iter()
        .map(|v| v.signum() * (v.abs() - best_theta).max(0.0))
        .collect()
}

#[test]
fn l1_projection_matches_brute_force_reference() {
    let mut rng = Rng::new(1);
    for trial in 0..200 {
        let d = 2 + (trial % 30);
        let mut x: Vec<f64> = rng.gaussians(d).iter().map(|v| v * 3.0).collect();
        let radius = 0.1 + rng.uniform() * 4.0;
        let reference = brute_force_l1(&x, radius);
        project_l1(&mut x, radius);
        for (a, b) in x.iter().zip(&reference) {
            assert!(
                (a - b).abs() < 1e-10,
                "trial {trial}: pivot {a} vs brute force {b}"
            );
        }
    }
}

#[test]
fn l1_projection_brute_force_on_adversarial_shapes() {
    // ties, zeros, one dominant coordinate, all-equal magnitudes
    let cases: Vec<(Vec<f64>, f64)> = vec![
        (vec![1.0, 1.0, 1.0, 1.0], 2.0),
        (vec![5.0, 0.0, 0.0], 1.0),
        (vec![-2.0, 2.0, -2.0, 2.0], 3.0),
        (vec![1e-12, 1.0, -1e-12], 0.5),
        (vec![3.0, -0.1, 1.0, -3.0], 2.0),
    ];
    for (x0, radius) in cases {
        let reference = brute_force_l1(&x0, radius);
        let mut x = x0.clone();
        project_l1(&mut x, radius);
        let l1: f64 = x.iter().map(|v| v.abs()).sum();
        assert!(l1 <= radius + 1e-9, "{x0:?}: left the ball ({l1})");
        for (a, b) in x.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-10, "{x0:?}: {a} vs {b}");
        }
    }
}

#[test]
fn box_degenerate_lo_equals_hi_pins_every_coordinate() {
    let c = Constraint::Box { lo: 0.7, hi: 0.7 };
    let mut x = vec![-3.0, 0.7, 12.0, 0.0];
    c.project(&mut x);
    assert_eq!(x, vec![0.7; 4]);
    assert!(c.contains(&x, 1e-12));
    // idempotent on the degenerate box too
    c.project(&mut x);
    assert_eq!(x, vec![0.7; 4]);
}

#[test]
fn box_with_no_violation_is_identity() {
    let c = Constraint::Box { lo: -1.0, hi: 1.0 };
    let inside = vec![0.3, -0.9999, 0.0, 1.0, -1.0];
    let mut x = inside.clone();
    c.project(&mut x);
    assert_eq!(x, inside, "interior/boundary points must be untouched");
    assert!(c.contains(&x, 0.0));
}

#[test]
fn metric_projection_with_identity_r_matches_euclidean_l2_and_l1() {
    // H = R^T R = I: the quadratic subproblem degenerates to the Euclidean
    // projection; the metric path must agree with the direct one.
    let mut rng = Rng::new(7);
    let proj = MetricProjector::from_r(&Mat::eye(9));
    for _ in 0..20 {
        let z: Vec<f64> = rng.gaussians(9).iter().map(|v| v * 4.0).collect();
        // l2
        let got = proj.project(&z, &Constraint::L2Ball { radius: 1.3 });
        let mut want = z.clone();
        project_l2(&mut want, 1.3);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8, "l2: {a} vs {b}");
        }
        // l1 (ADMM path) — also cross-checked against the brute force
        let got = proj.project(&z, &Constraint::L1Ball { radius: 2.0 });
        let want = brute_force_l1(&z, 2.0);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "l1: {a} vs {b}");
        }
    }
}

#[test]
fn metric_projection_with_identity_r_matches_euclidean_box() {
    let mut rng = Rng::new(9);
    let proj = MetricProjector::from_r(&Mat::eye(6));
    let cons = Constraint::Box { lo: -0.5, hi: 0.25 };
    for _ in 0..20 {
        let z: Vec<f64> = rng.gaussians(6).iter().map(|v| v * 2.0).collect();
        let got = proj.project(&z, &cons);
        let mut want = z.clone();
        cons.project(&mut want);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "box: {a} vs {b}");
        }
        assert!(cons.contains(&got, 1e-9));
    }
}

#[test]
fn metric_projection_unconstrained_is_identity() {
    let mut rng = Rng::new(11);
    let a = Mat::gaussian(40, 5, &mut rng);
    let r = hdpw::linalg::qr::qr_r(&a);
    let proj = MetricProjector::from_r(&r);
    let z: Vec<f64> = rng.gaussians(5);
    let got = proj.project(&z, &Constraint::Unconstrained);
    assert_eq!(got, z);
}
