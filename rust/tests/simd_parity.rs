//! SIMD-vs-native parity — the numerics contract of the microkernel layer
//! (ISSUE 6 satellite).
//!
//! The native executor is the bit-exact reference; the simd kernels change
//! accumulation order (lane-parallel partial sums) and contract mul+add
//! pairs into FMA. Both are exact-rounding rearrangements of the same sum,
//! so the elementwise drift is bounded by re-association error alone:
//! for every kernel and shape here we enforce
//!
//! ```text
//! |simd - native| <= 1e-12 * (1 + |native|)
//! ```
//!
//! which holds with orders of magnitude to spare for this crate's shapes
//! (dot products of length <= a few thousand: worst-case re-association
//! error ~ n * eps * Σ|terms| ~ 1e-13 relative at n = 4096). Two kernel
//! families are held to *bitwise* equality instead:
//!
//! * `row_add` / `row_sub` — lanewise with no FMA, so no reordering at all;
//!   the CountSketch scatter fold is built on them and must stay
//!   bit-identical under every kernel set.
//! * the dispatched kernels vs the explicit `F64x4Scalar` generics when the
//!   detected arch is AVX2 (or scalar) — `F64x4Scalar` mirrors AVX2's lane
//!   count, FMA (`f64::mul_add` is the same fused operation), and pinned
//!   horizontal-sum tree, so the monomorphized bodies must agree bit for
//!   bit.
//!
//! The last test runs whole solver traces (pwsgd + ihs) under
//! `executor: simd` vs `executor: native` through the coordinator; the
//! kernel-level drift is amplified by the iteration loop, so traces are
//! compared in a wider band (5% relative with a 1e-6 absolute floor)
//! rather than the kernel tolerance. The bitwise golden fixtures stay
//! pinned to the native executor in `solver_golden.rs`.

use hdpw::backend::Backend;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
use hdpw::linalg::{blas, CsrMat, Mat};
use hdpw::simd::{self, F64x4Scalar, SimdArch};
use hdpw::sketch::{self, apply_streamed_with, RowOps, SketchKind};
use hdpw::util::rng::Rng;

/// The documented kernel-level parity bound (see module docs).
const TOL: f64 = 1e-12;

fn close(got: f64, want: f64) -> bool {
    (got - want).abs() <= TOL * (1.0 + want.abs())
}

fn assert_vec_close(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(close(*g, *w), "{what}[{i}]: simd {g} vs native {w}");
    }
}

fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
    }
}

/// Odd / even / tiny / large shapes, chosen to hit every tail class of a
/// 2-, 4- and 8-lane kernel (len mod lane in all residues, below one lane,
/// below one unrolled stripe, and well above the parallel thresholds).
const SHAPES: [(usize, usize); 9] = [
    (1, 1),
    (2, 3),
    (5, 4),
    (7, 13),
    (31, 8),
    (64, 17),
    (129, 33),
    (512, 100),
    (2048, 64),
];

#[test]
fn gemv_and_gemv_t_match_native_across_shapes() {
    let mut rng = Rng::new(101);
    for &(n, d) in &SHAPES {
        let a = Mat::gaussian(n, d, &mut rng);
        let x = rng.gaussians(d);
        let want = blas::gemv(&a, &x);
        for threads in [1, 4] {
            let got = simd::gemv(&a, &x, threads);
            assert_vec_close(&got, &want, &format!("gemv {n}x{d} t={threads}"));
        }
        let y = rng.gaussians(n);
        let want_t = blas::gemv_t(&a, &y);
        for threads in [1, 4] {
            let got = simd::gemv_t(&a, &y, threads);
            assert_vec_close(&got, &want_t, &format!("gemv_t {n}x{d} t={threads}"));
        }
    }
}

#[test]
fn fused_grad_and_residual_match_native_across_shapes() {
    let mut rng = Rng::new(102);
    for &(n, d) in &SHAPES {
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        let x = rng.gaussians(d);
        let scale = 2.0 * n as f64;
        let want = blas::fused_grad(&a, &b, &x, scale);
        let want_r = blas::residual_sq(&a, &b, &x);
        for threads in [1, 4] {
            let got = simd::fused_grad(&a, &b, &x, scale, threads);
            assert_vec_close(&got, &want, &format!("fused_grad {n}x{d} t={threads}"));
            let got_r = simd::residual_sq(&a, &b, &x, threads);
            assert!(
                close(got_r, want_r),
                "residual_sq {n}x{d} t={threads}: {got_r} vs {want_r}"
            );
        }
    }
}

#[test]
fn gemm_matches_native_including_ragged_tails() {
    let mut rng = Rng::new(103);
    // inner dims and output widths straddling the register tile (lanes * 4)
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (5, 7, 3),
        (33, 31, 29),
        (64, 64, 65),
        (100, 17, 130),
        (128, 40, 32),
    ] {
        let a = Mat::gaussian(m, k, &mut rng);
        let b = Mat::gaussian(k, n, &mut rng);
        let want = blas::gemm(&a, &b);
        for threads in [1, 4] {
            let got = simd::gemm(&a, &b, threads);
            assert_eq!((got.rows, got.cols), (m, n));
            for i in 0..m {
                assert_vec_close(got.row(i), want.row(i), &format!("gemm {m}x{k}x{n} row {i}"));
            }
        }
    }
}

#[test]
fn row_ops_on_unaligned_slices_and_lane_tails() {
    // Mat data is 64-byte aligned, but the kernels must accept arbitrary
    // subslices: every offset residue mod 8 doubles as an alignment test
    // (offset 1 from a 64-byte base is an 8-byte-aligned, cache-line-
    // straddling pointer).
    let mut rng = Rng::new(104);
    let parent_src = rng.gaussians(512);
    let parent_dst = rng.gaussians(512);
    for off in 0..8usize {
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 63, 200] {
            let src = &parent_src[off..off + len];
            let mut simd_dst = parent_dst[off..off + len].to_vec();
            let mut ref_dst = simd_dst.clone();

            simd::row_add(&mut simd_dst, src);
            for (o, v) in ref_dst.iter_mut().zip(src) {
                *o += v;
            }
            assert_bits_eq(&simd_dst, &ref_dst, &format!("row_add off={off} len={len}"));

            simd::row_sub(&mut simd_dst, src);
            for (o, v) in ref_dst.iter_mut().zip(src) {
                *o -= v;
            }
            assert_bits_eq(&simd_dst, &ref_dst, &format!("row_sub off={off} len={len}"));

            simd::row_axpy(&mut simd_dst, -0.75, src);
            for (o, v) in ref_dst.iter_mut().zip(src) {
                *o += -0.75 * v; // mul-then-add reference: axpy may fuse
            }
            assert_vec_close(&simd_dst, &ref_dst, &format!("row_axpy off={off} len={len}"));
        }
    }
}

#[test]
fn fwht_matches_native_across_sizes() {
    let mut rng = Rng::new(105);
    for n in [1usize, 2, 4, 8, 32, 256, 4096] {
        let mut got = rng.gaussians(n);
        let mut want = got.clone();
        simd::fwht_vec(&mut got);
        sketch::fwht::fwht_vec(&mut want);
        assert_vec_close(&got, &want, &format!("fwht_vec n={n}"));
    }
    // odd/even panel widths around the lane width, serial and parallel
    for &(n, d) in &[(8usize, 1usize), (64, 3), (128, 5), (256, 9), (1024, 40)] {
        let m = Mat::gaussian(n, d, &mut rng);
        let mut want = m.clone();
        sketch::fwht::fwht_mat(&mut want);
        for threads in [1, 4] {
            let mut got = m.clone();
            simd::fwht_mat(&mut got, threads);
            for i in 0..n {
                assert_vec_close(got.row(i), want.row(i), &format!("fwht_mat {n}x{d} row {i}"));
            }
        }
        let signs = rng.signs(n);
        let mut got = m.clone();
        let mut nat = m.clone();
        simd::randomized_hadamard(&mut got, &signs, 4);
        sketch::fwht::randomized_hadamard(&mut nat, &signs);
        assert!(
            got.max_abs_diff(&nat) <= TOL * (1.0 + nat.max_abs_diff(&Mat::zeros(n, d))),
            "randomized_hadamard {n}x{d}"
        );
    }
}

#[test]
fn explicit_scalar_generics_match_native() {
    // The generic kernel bodies instantiated with the portable lane type,
    // bypassing dispatch — this pins the shared code path all arch wrappers
    // monomorphize, on every host.
    let mut rng = Rng::new(106);
    let a = Mat::gaussian(101, 23, &mut rng);
    let x = rng.gaussians(23);
    let b = rng.gaussians(101);

    // SAFETY: F64x4Scalar is plain Rust (no instruction-set requirement)
    // and all slice lengths match the kernels' documented preconditions.
    let dot = unsafe { simd::kernels::row_dot::<F64x4Scalar>(a.row(3), &x) };
    assert!(close(dot, blas::dot(a.row(3), &x)), "row_dot");

    let mut got = vec![0.0; 101];
    // SAFETY: as above; `got.len() == a.rows`, `x.len() == a.cols`.
    unsafe { simd::kernels::gemv_rows::<F64x4Scalar>(&a, &x, &mut got, 0, 101) };
    assert_vec_close(&got, &blas::gemv(&a, &x), "gemv_rows::<F64x4Scalar>");

    let mut g = vec![0.0; 23];
    // SAFETY: as above; `g.len() == a.cols == x.len()`, `b.len() == a.rows`.
    unsafe { simd::kernels::fused_grad_rows::<F64x4Scalar>(&a, &b, &x, &mut g, 0, 101) };
    let want = blas::fused_grad(&a, &b, &x, 1.0);
    assert_vec_close(&g, &want, "fused_grad_rows::<F64x4Scalar>");

    // SAFETY: as above.
    let r = unsafe { simd::kernels::residual_sq_rows::<F64x4Scalar>(&a, &b, &x, 0, 101) };
    assert!(close(r, blas::residual_sq(&a, &b, &x)), "residual_sq_rows");

    let mut v = rng.gaussians(128);
    let mut vw = v.clone();
    // SAFETY: as above; length is a power of two.
    unsafe { simd::kernels::fwht_butterflies::<F64x4Scalar>(&mut v) };
    sketch::fwht::fwht_vec(&mut vw);
    let scale = 1.0 / (128f64).sqrt();
    for (g, w) in v.iter().zip(&vw) {
        assert!(close(g * scale, *w), "fwht_butterflies: {g} vs {w}");
    }
}

#[test]
fn dispatched_kernels_bit_match_scalar_generics_on_avx2() {
    // F64x4Scalar deliberately mirrors AVX2: 4 lanes, f64::mul_add (the
    // same fused operation as vfmadd), and the AVX2 hadd-shaped horizontal
    // sum tree (l0+l2)+(l1+l3). On an AVX2 host the dispatched kernels must
    // therefore agree with the explicit scalar generics *bitwise*; on the
    // scalar fallback they are trivially the same code. NEON (2 lanes) and
    // AVX-512 (8 lanes) partition the sums differently and are only held to
    // the 1e-12 band by the other tests.
    match simd::arch() {
        SimdArch::Avx2 | SimdArch::Scalar => {}
        other => {
            eprintln!(
                "SKIP bitwise scalar check: arch {} has a different lane count",
                other.name()
            );
            return;
        }
    }
    let mut rng = Rng::new(107);
    for &(n, d) in &[(7usize, 5usize), (64, 17), (513, 33)] {
        let a = Mat::gaussian(n, d, &mut rng);
        let x = rng.gaussians(d);
        let b = rng.gaussians(n);
        let got = simd::gemv(&a, &x, 1);
        let mut want = vec![0.0; n];
        // SAFETY: portable lane type; lengths match the preconditions.
        unsafe { simd::kernels::gemv_rows::<F64x4Scalar>(&a, &x, &mut want, 0, n) };
        assert_bits_eq(&got, &want, &format!("gemv bitwise {n}x{d}"));

        let got = simd::fused_grad(&a, &b, &x, 1.0, 1);
        let mut want = vec![0.0; d];
        // SAFETY: as above.
        unsafe {
            simd::kernels::fused_grad_rows::<F64x4Scalar>(&a, &b, &x, &mut want, 0, n);
            simd::kernels::scale_slice::<F64x4Scalar>(&mut want, 1.0);
        }
        assert_bits_eq(&got, &want, &format!("fused_grad bitwise {n}x{d}"));
    }
    let mut got = rng.gaussians(256);
    let mut want = got.clone();
    simd::fwht_vec(&mut got);
    // SAFETY: as above; length is a power of two.
    unsafe {
        simd::kernels::fwht_butterflies::<F64x4Scalar>(&mut want);
        simd::kernels::scale_slice::<F64x4Scalar>(&mut want, 1.0 / 16.0);
    }
    assert_bits_eq(&got, &want, "fwht bitwise");
}

/// Random CSR matrix with ~density nonzeros plus its dense twin; row 0 is
/// forced empty and row 1 fully dense to pin both edge classes.
fn sparse_pair(n: usize, d: usize, density: f64, seed: u64) -> (CsrMat, Mat) {
    let mut rng = Rng::new(seed);
    let dense = Mat::from_fn(n, d, |i, _| {
        if i == 0 {
            0.0
        } else if i == 1 || rng.uniform() < density {
            rng.gaussian()
        } else {
            0.0
        }
    });
    (CsrMat::from_dense(&dense), dense)
}

#[test]
fn csr_kernels_match_sparse_reference() {
    let (csr, _) = sparse_pair(120, 19, 0.3, 108);
    let mut rng = Rng::new(109);
    let x = rng.gaussians(19);
    for i in 0..120 {
        let got = simd::csr_row_dot(&csr, i, &x);
        let want = csr.row_dot(i, &x);
        assert!(close(got, want), "csr_row_dot row {i}: {got} vs {want}");
    }
    let b = rng.gaussians(120);
    for bs in [1usize, 7, 64] {
        let tau: Vec<usize> = (0..bs).map(|_| rng.below(120)).collect();
        let got = simd::csr_batch_grad(&csr, &tau, &b, &x, 3.5);
        let want = csr.batch_grad(&tau, &b, &x, 3.5);
        assert_vec_close(&got, &want, &format!("csr_batch_grad bs={bs}"));
    }
}

#[test]
fn countsketch_scatter_bitwise_under_simd_row_ops() {
    // CountSketch's fold is pure add/sub — no FMA, no reordering — so the
    // simd kernel set must reproduce the scalar fold bit for bit.
    let mut rng = Rng::new(110);
    let a = Mat::gaussian(301, 5, &mut rng);
    let sk = SketchKind::CountSketch.build(48, 301, &mut rng);
    let (scalar, _) = apply_streamed_with(sk.as_ref(), &a, Some(16), 4, &RowOps::SCALAR);
    let (simded, shards) = apply_streamed_with(sk.as_ref(), &a, Some(16), 4, &simd::row_ops());
    assert!(shards > 1, "expected a real streamed fold");
    assert_bits_eq(&scalar.data[..], &simded.data[..], "countsketch fold");
}

#[test]
fn sparse_embed_fold_within_tolerance_under_simd_row_ops() {
    // SparseEmbed's fold is an axpy per bucket: the simd set fuses the
    // mul+add, so this is tolerance- (not bit-) gated.
    let mut rng = Rng::new(111);
    let a = Mat::gaussian(301, 5, &mut rng);
    let sk = SketchKind::SparseEmbed.build(48, 301, &mut rng);
    let (scalar, _) = apply_streamed_with(sk.as_ref(), &a, Some(16), 4, &RowOps::SCALAR);
    let (simded, shards) = apply_streamed_with(sk.as_ref(), &a, Some(16), 4, &simd::row_ops());
    assert!(shards > 1, "expected a real streamed fold");
    assert_vec_close(&simded.data[..], &scalar.data[..], "sparse_embed fold");
}

fn trace_request(solver: &str, max_iters: usize, executor: &str) -> JobRequest {
    let mut req = JobRequest::default();
    req.dataset = "syn2".into();
    req.n = 2048;
    req.solver = solver.into();
    req.max_iters = max_iters;
    req.batch_size = 16;
    req.seed = 7;
    req.trials = 1;
    req.time_budget = 1e9; // stop on iteration count only
    req.reuse_precond = false;
    req.warm_start = false;
    req.format = "dense".into();
    req.executor = executor.into();
    req
}

#[test]
fn solver_traces_agree_between_simd_and_native_executors() {
    let coord = Coordinator::new(Backend::native(), CoordinatorConfig::default());
    for (solver, iters) in [("pwsgd", 400usize), ("ihs", 15)] {
        let nat = coord.run_job(&trace_request(solver, iters, "native")).unwrap();
        let sim = coord.run_job(&trace_request(solver, iters, "simd")).unwrap();
        assert!(
            (sim.f_star - nat.f_star).abs() <= 1e-9 * (1.0 + nat.f_star.abs()),
            "{solver}: f* drifted: {} vs {}",
            sim.f_star,
            nat.f_star
        );
        assert_eq!(sim.best.trace.len(), nat.best.trace.len(), "{solver}: trace length");
        for (k, (ps, pn)) in sim.best.trace.iter().zip(&nat.best.trace).enumerate() {
            assert_eq!(ps.iters, pn.iters, "{solver}: trace[{k}] iteration count");
            let rs = ((ps.f - sim.f_star) / sim.f_star.max(1e-300)).max(0.0);
            let rn = ((pn.f - nat.f_star) / nat.f_star.max(1e-300)).max(0.0);
            // the iteration loop amplifies the 1e-12 kernel drift, so the
            // trace band is wider: 5% relative with a 1e-6 absolute floor
            assert!(
                (rs - rn).abs() <= 1e-6 + 0.05 * rn.abs(),
                "{solver}: trace[{k}] rel-err diverged: simd {rs} vs native {rn}"
            );
        }
        assert!(
            sim.best_rel_err <= nat.best_rel_err.max(1e-9) * 10.0 + 1e-6,
            "{solver}: simd run converged much worse ({} vs {})",
            sim.best_rel_err,
            nat.best_rel_err
        );
    }
    assert!(
        coord.backend().simd_calls() > 0,
        "simd executor was never dispatched to during the simd runs"
    );
}
