//! Out-of-core acceptance suite (ISSUE 10).
//!
//! * EVERY registered solver runs on both on-disk formats (`mmapdense`,
//!   `libsvm-chunked`) across shard heights — including `chunk_rows = 1`
//!   and `chunk_rows > n` — and reproduces the resident twin's solve
//!   **bitwise**: same iterate, same objective, same trace, under the
//!   native executor.
//! * Injected I/O faults (mid-read EOF, short header, non-finite payload,
//!   permission denied, truncated file) each surface over the serve wire as
//!   a structured id-tagged job error line — never a worker panic — while
//!   a transient `TimedOut` retries once and the job still solves.
//! * The over-budget acceptance: a dataset whose design is 2x the
//!   [`MemBudget`] limit solves through the shard cache with peak tracked
//!   bytes below the budget and a trace bitwise-identical to the in-memory
//!   run.

use hdpw::backend::Backend;
use hdpw::coordinator::{server, Coordinator, CoordinatorConfig};
use hdpw::data::{chunked, mmap, Dataset, OnDiskDesign};
use hdpw::linalg::{blas, CsrMat, Mat};
use hdpw::solvers::{self, SolveReport, Solver, SolverOpts};
use hdpw::util::json::Json;
use hdpw::util::mem::MemBudget;
use hdpw::util::rng::Rng;
use std::io::{Cursor, Write};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hdpw_ooc_it_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn dense_fixture(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a = Mat::gaussian(n, d, &mut rng);
    let xt = rng.gaussians(d);
    let mut b = blas::gemv(&a, &xt);
    for v in &mut b {
        *v += 0.25 * rng.gaussian();
    }
    (a, b)
}

fn sparse_fixture(n: usize, d: usize, seed: u64) -> (CsrMat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let dense = Mat::from_fn(n, d, |_, _| {
        if rng.uniform() < 0.3 {
            rng.gaussian()
        } else {
            0.0
        }
    });
    let xt = rng.gaussians(d);
    let mut b = blas::gemv(&dense, &xt);
    for v in &mut b {
        *v += 0.25 * rng.gaussian();
    }
    (CsrMat::from_dense(&dense), b)
}

/// Fixed options for the parity runs: no env-derived knobs, and a time
/// budget that can never truncate the iteration count (bitwise comparisons
/// must not depend on machine load).
fn parity_opts() -> SolverOpts {
    let mut o = SolverOpts::default();
    o.batch_size = 8;
    o.max_iters = 60;
    o.chunk = 20;
    o.time_budget = 1e9;
    o.seed = 5;
    o
}

fn assert_bitwise(want: &SolveReport, got: &SolveReport, ctx: &str) {
    assert_eq!(want.iters, got.iters, "{ctx}: iteration count");
    assert_eq!(want.x.len(), got.x.len(), "{ctx}: iterate dimension");
    for (k, (w, g)) in want.x.iter().zip(&got.x).enumerate() {
        assert_eq!(w.to_bits(), g.to_bits(), "{ctx}: x[{k}] drifted");
    }
    assert_eq!(
        want.f_final.to_bits(),
        got.f_final.to_bits(),
        "{ctx}: f_final drifted"
    );
    assert_eq!(want.trace.len(), got.trace.len(), "{ctx}: trace length");
    for (k, (w, g)) in want.trace.iter().zip(&got.trace).enumerate() {
        assert_eq!(w.iters, g.iters, "{ctx}: trace[{k}].iters");
        assert_eq!(w.f.to_bits(), g.f.to_bits(), "{ctx}: trace[{k}].f drifted");
    }
}

#[test]
fn every_solver_on_mmapdense_is_bitwise_to_the_resident_dense_twin() {
    let dir = test_dir("mmap_parity");
    let (a, b) = dense_fixture(192, 6, 11);
    let path = dir.join("parity.hdpw");
    mmap::write(&path, &a, &b).unwrap();
    let twin = Dataset::dense("parity", a, b, None);
    let backend = Backend::native();
    for name in solvers::all_names() {
        let solver = solvers::by_name(name).unwrap();
        let opts = parity_opts();
        let want = solver.solve(&backend, &twin, &opts).unwrap();
        // one row per shard, an odd mid height, one shard (= n), chunk > n
        for chunk_rows in [1usize, 7, 192, 1000] {
            let od =
                OnDiskDesign::open_mmap(&path, MemBudget::unlimited(), chunk_rows).unwrap();
            let ds = Dataset::from_on_disk("parity", od);
            let got = solver.solve(&backend, &ds, &opts).unwrap();
            assert_bitwise(&want, &got, &format!("{name} mmapdense ck={chunk_rows}"));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_solver_on_chunked_csr_is_bitwise_to_the_resident_csr_twin() {
    let dir = test_dir("chunk_parity");
    let (csr, b) = sparse_fixture(192, 6, 12);
    let heights = [1usize, 9, 192, 1000];
    for &cr in &heights {
        chunked::write_chunks(&dir.join(format!("ck{cr}")), &csr, &b, cr).unwrap();
    }
    let twin = Dataset::from_csr("parity", csr, b, None);
    let backend = Backend::native();
    for name in solvers::all_names() {
        let solver = solvers::by_name(name).unwrap();
        let opts = parity_opts();
        let want = solver.solve(&backend, &twin, &opts).unwrap();
        for &cr in &heights {
            let od = OnDiskDesign::open_chunked(
                &dir.join(format!("ck{cr}")),
                MemBudget::unlimited(),
                cr,
            )
            .unwrap();
            let ds = Dataset::from_on_disk("parity", od);
            let got = solver.solve(&backend, &ds, &opts).unwrap();
            assert_bitwise(&want, &got, &format!("{name} chunked ck={cr}"));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[derive(Clone)]
struct VecWriter(Arc<Mutex<Vec<u8>>>);

impl Write for VecWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn wire(c: &Arc<Coordinator>, input: String) -> Vec<Json> {
    let out = Arc::new(Mutex::new(Vec::new()));
    server::handle_connection(c, Cursor::new(input), VecWriter(Arc::clone(&out))).unwrap();
    let bytes = out.lock().unwrap().clone();
    String::from_utf8(bytes)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect()
}

fn line_with_id(lines: &[Json], id: f64) -> Json {
    lines
        .iter()
        .find(|j| j.get("id").and_then(Json::as_f64) == Some(id))
        .cloned()
        .unwrap_or_else(|| panic!("no response line with id {id} among {} lines", lines.len()))
}

fn error_of(line: &Json, what: &str) -> String {
    line.get("error")
        .and_then(Json::as_str)
        .unwrap_or_else(|| panic!("{what}: expected an error line, got a result"))
        .to_string()
}

#[test]
fn injected_io_faults_surface_as_id_tagged_error_lines_over_the_wire() {
    chunked::clear_faults();
    let budget = MemBudget::unlimited();
    let c = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers: 1,
            max_queue: 8,
            mem_budget: Arc::clone(&budget),
            ..CoordinatorConfig::default()
        },
    ));
    let root = test_dir("faults");
    let (csr, b) = sparse_fixture(64, 5, 13);

    // baseline: the clean directory solves, so every failure below is the
    // injected fault and nothing else
    let clean = root.join("clean");
    chunked::write_chunks(&clean, &csr, &b, 16).unwrap();
    let lines = wire(
        &c,
        format!(
            "{{\"id\":1,\"solver\":\"exact\",\"dataset\":\"libsvm-chunked:{}\"}}\n",
            clean.display()
        ),
    );
    let l = line_with_id(&lines, 1.0);
    assert!(l.get("error").is_none(), "clean baseline must solve");
    assert!(l.get("best_f").is_some(), "result line carries the objective");

    // mid-read EOF: 64 bytes delivered faithfully, then the stream ends
    let eof_dir = root.join("fault_eof");
    chunked::write_chunks(&eof_dir, &csr, &b, 16).unwrap();
    chunked::inject_fault("fault_eof", 64, std::io::ErrorKind::UnexpectedEof);
    let lines = wire(
        &c,
        format!(
            "{{\"id\":2,\"solver\":\"exact\",\"dataset\":\"libsvm-chunked:{}\"}}\n",
            eof_dir.display()
        ),
    );
    let msg = error_of(&line_with_id(&lines, 2.0), "mid-read EOF");
    assert!(msg.contains("injected fault"), "{msg}");

    // short header: a shard without the `# hdpw: cols=` header line
    let hdr_dir = root.join("fault_hdr");
    std::fs::create_dir_all(&hdr_dir).unwrap();
    std::fs::write(hdr_dir.join("chunk_00000.svm"), "1 1:2\n").unwrap();
    let lines = wire(
        &c,
        format!(
            "{{\"id\":3,\"solver\":\"exact\",\"dataset\":\"libsvm-chunked:{}\"}}\n",
            hdr_dir.display()
        ),
    );
    let msg = error_of(&line_with_id(&lines, 3.0), "short header");
    assert!(msg.contains("short header"), "{msg}");

    // non-finite payload: a NaN feature value in an otherwise valid shard
    let nan_dir = root.join("fault_nan");
    std::fs::create_dir_all(&nan_dir).unwrap();
    std::fs::write(nan_dir.join("chunk_00000.svm"), "# hdpw: cols=3\n1 1:nan\n").unwrap();
    let lines = wire(
        &c,
        format!(
            "{{\"id\":4,\"solver\":\"exact\",\"dataset\":\"libsvm-chunked:{}\"}}\n",
            nan_dir.display()
        ),
    );
    let msg = error_of(&line_with_id(&lines, 4.0), "non-finite payload");
    assert!(msg.contains("non-finite"), "{msg}");

    // permission denied on the first byte of a chunk read
    let perm_dir = root.join("fault_perm");
    chunked::write_chunks(&perm_dir, &csr, &b, 16).unwrap();
    chunked::inject_fault("fault_perm", 0, std::io::ErrorKind::PermissionDenied);
    let lines = wire(
        &c,
        format!(
            "{{\"id\":5,\"solver\":\"exact\",\"dataset\":\"libsvm-chunked:{}\"}}\n",
            perm_dir.display()
        ),
    );
    let msg = error_of(&line_with_id(&lines, 5.0), "permission denied");
    assert!(msg.contains("injected fault"), "{msg}");

    // transient TimedOut mid-read: retried once, the job still SOLVES, and
    // the retry is visible on the coordinator budget's counter
    let tmo_dir = root.join("fault_tmo");
    chunked::write_chunks(&tmo_dir, &csr, &b, 16).unwrap();
    let retries_before = budget.io_retries();
    chunked::inject_fault("fault_tmo", 16, std::io::ErrorKind::TimedOut);
    let lines = wire(
        &c,
        format!(
            "{{\"id\":6,\"solver\":\"exact\",\"dataset\":\"libsvm-chunked:{}\"}}\n",
            tmo_dir.display()
        ),
    );
    let l = line_with_id(&lines, 6.0);
    assert!(
        l.get("error").is_none(),
        "a transient fault must be retried, not failed: {:?}",
        l.get("error").and_then(Json::as_str)
    );
    assert!(
        budget.io_retries() > retries_before,
        "the transient retry must be counted"
    );

    // mmapdense short header: fewer bytes than magic + shape
    let short = root.join("short.hdpw");
    std::fs::write(&short, b"HDPW").unwrap();
    let lines = wire(
        &c,
        format!(
            "{{\"id\":7,\"solver\":\"exact\",\"dataset\":\"mmapdense:{}\"}}\n",
            short.display()
        ),
    );
    let msg = error_of(&line_with_id(&lines, 7.0), "mmapdense short header");
    assert!(msg.contains("mmapdense"), "{msg}");

    // mmapdense truncated payload: valid header, matrix bytes cut short
    let trunc = root.join("trunc.hdpw");
    let (a, vb) = dense_fixture(16, 3, 14);
    mmap::write(&trunc, &a, &vb).unwrap();
    let raw = std::fs::read(&trunc).unwrap();
    std::fs::write(&trunc, &raw[..raw.len() - 9]).unwrap();
    let lines = wire(
        &c,
        format!(
            "{{\"id\":8,\"solver\":\"exact\",\"dataset\":\"mmapdense:{}\"}}\n",
            trunc.display()
        ),
    );
    let msg = error_of(&line_with_id(&lines, 8.0), "mmapdense truncation");
    assert!(msg.contains("truncated"), "{msg}");

    chunked::clear_faults();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn over_budget_dataset_solves_below_the_budget_and_bitwise_matches_memory() {
    // the ISSUE 10 acceptance criterion: the design is 32768 x 8 = 2 MiB on
    // disk — double the 1 MiB budget — and the solve must (a) complete,
    // (b) keep peak *tracked* bytes under the budget (8 shards of 256 KiB
    // cycling through the LRU cache), and (c) reproduce the in-memory run's
    // trace bit for bit.
    let dir = test_dir("acceptance");
    let (a, b) = dense_fixture(32_768, 8, 21);
    let path = dir.join("big.hdpw");
    mmap::write(&path, &a, &b).unwrap();

    let mut opts = parity_opts();
    opts.batch_size = 16;
    opts.max_iters = 120;
    opts.chunk = 40;
    let solver = solvers::by_name("sgd").unwrap();
    let backend = Backend::native();

    let twin = Dataset::dense("big", a, b, None);
    let want = solver.solve(&backend, &twin, &opts).unwrap();
    drop(twin);

    let budget = MemBudget::with_limit_mb(1);
    let od = OnDiskDesign::open_mmap(&path, Arc::clone(&budget), 4096).unwrap();
    let ds = Dataset::from_on_disk("big", od);
    let got = solver.solve(&backend, &ds, &opts).unwrap();
    assert_bitwise(&want, &got, "sgd over-budget mmapdense");

    assert!(budget.peak() > 0, "shard loads must be tracked");
    assert!(
        budget.peak() <= 1 << 20,
        "peak tracked bytes {} exceeded the 1 MiB budget",
        budget.peak()
    );
    assert!(
        budget.shard_faults() >= 8,
        "a full objective pass faults every shard in (got {})",
        budget.shard_faults()
    );
    assert!(
        budget.shard_evictions() > 0,
        "2 MiB of shards cannot stay resident under 1 MiB without evictions"
    );
    drop(ds);
    assert_eq!(
        budget.shard_resident_bytes(),
        0,
        "dropping the dataset releases all shard residency"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
