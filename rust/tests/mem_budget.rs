//! Memory-budget acceptance suite (ISSUE 4, extended by ISSUE 8).
//!
//! * EVERY registered solver — the HD family included, now that step 2 is
//!   held implicitly on CSR — runs a sparse dataset end-to-end through the
//!   coordinator under a 128 MiB budget with **zero** densifications, zero
//!   tracked bytes, and a bitwise-stable solution across repeat runs.
//! * An over-budget solve surfaces as a structured job error — through
//!   `run_job` and over the serve loop's wire — never a panic or an OOM.
//!   That includes IHS's *in-loop* re-sketch: a whole-matrix-densifying
//!   sketch (SRHT) on CSR charges its scoped buffer per iteration, and an
//!   over-budget charge propagates out of `StepRule::step` as the job's
//!   error line, id attached.
//! * Admission control queues a dense HD job until headroom appears and
//!   rejects jobs that can never fit; sparse HD jobs estimate 0 and are
//!   admitted outright.

use hdpw::backend::Backend;
use hdpw::coordinator::{server, Coordinator, CoordinatorConfig, JobRequest};
use hdpw::util::json::Json;
use hdpw::util::mem::MemBudget;
use std::io::{Cursor, Write};
use std::sync::{Arc, Mutex};

fn coord_with_budget(budget: Arc<MemBudget>) -> Arc<Coordinator> {
    Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers: 2,
            max_queue: 8,
            mem_budget: budget,
            ..CoordinatorConfig::default()
        },
    ))
}

fn sparse_req(solver: &str, n: usize) -> JobRequest {
    let mut req = JobRequest::default();
    req.dataset = "syn2".into();
    req.format = "sparse".into();
    req.density = 0.1;
    req.n = n;
    req.solver = solver.into();
    req.max_iters = 60;
    req.batch_size = 8;
    req.time_budget = 20.0;
    // pin the protocol knobs the CI env variants flip: with reuse on, a
    // cached artifact would (correctly) keep bytes charged across jobs,
    // which is exactly what the used()==0 release assertions must not see
    req.reuse_precond = false;
    req.warm_start = false;
    req
}

#[test]
fn every_solver_on_csr_is_zero_densify_and_bitwise_stable_under_128mb() {
    // the ISSUE 8 acceptance criterion: all solvers — including the HD
    // family, whose step 2 is now implicit on CSR — complete on a sparse
    // dataset under a 128 MiB budget without a single densification, and
    // repeat runs reproduce the solution bit-for-bit
    let budget = MemBudget::with_limit_mb(128);
    let c = coord_with_budget(Arc::clone(&budget));
    let c2 = coord_with_budget(MemBudget::with_limit_mb(128));
    for solver in hdpw::solvers::all_names() {
        let res = c.run_job(&sparse_req(solver, 1024)).unwrap();
        assert!(res.sparse, "{solver}: expected the CSR pipeline");
        assert_eq!(
            res.densify_events, 0,
            "{solver}: a CSR solve must report densify_events == 0"
        );
        assert_eq!(
            res.mem_est_bytes, 0,
            "{solver}: nothing materializes, nothing is estimated"
        );
        // bitwise stability: the same request on a fresh coordinator (fresh
        // dataset build, fresh rng streams from the same seed) reproduces
        // the iterate and objective exactly
        let rerun = c2.run_job(&sparse_req(solver, 1024)).unwrap();
        assert_eq!(res.best.x, rerun.best.x, "{solver}: iterate must be bitwise stable");
        assert_eq!(
            res.best_f.to_bits(),
            rerun.best_f.to_bits(),
            "{solver}: objective must be bitwise stable"
        );
        assert_eq!(
            res.best.trace.len(),
            rerun.best.trace.len(),
            "{solver}: trace shape must be stable"
        );
        for (a, b) in res.best.trace.iter().zip(&rerun.best.trace) {
            assert_eq!(a.f.to_bits(), b.f.to_bits(), "{solver}: trace f drifted");
        }
    }
    assert_eq!(
        budget.densify_events(),
        0,
        "no stage on the CSR path may request a dense view"
    );
    assert_eq!(budget.peak(), 0, "zero tracked bytes end-to-end");
}

#[test]
fn hd_solver_on_csr_holds_no_buffer_and_never_densifies() {
    // pre-ISSUE-8 behavior: one charged padded-buffer materialization per
    // HD job on CSR. The implicit step 2 eliminates the buffer entirely —
    // the budget must see nothing at all.
    let budget = MemBudget::unlimited();
    let c = coord_with_budget(Arc::clone(&budget));
    let res = c.run_job(&sparse_req("hdpwbatchsgd", 1000)).unwrap();
    assert_eq!(res.mem_est_bytes, 0, "implicit HD estimates nothing");
    assert_eq!(res.densify_events, 0, "implicit HD materializes nothing");
    assert_eq!(budget.peak(), 0, "no padded buffer was ever resident");
    assert_eq!(budget.used(), 0);
    // and the accelerated variant shares the path
    let res2 = c.run_job(&sparse_req("hdpwaccbatchsgd", 1000)).unwrap();
    assert_eq!(res2.densify_events, 0);
    assert_eq!(budget.peak(), 0);
}

#[test]
fn over_budget_job_is_an_error_not_a_panic() {
    // 1 MiB budget; DENSE hdpw on n=16384 x 20 needs ~2.75 MiB for the HD
    // buffer (the sparse variant of this request now runs implicit and
    // fits trivially — see admission_charges_nothing tests)
    let budget = MemBudget::with_limit_mb(1);
    let c = coord_with_budget(Arc::clone(&budget));
    let mut req = sparse_req("hdpwbatchsgd", 16_384);
    req.format = "dense".into();
    req.time_budget = 2.0;
    let err = c.run_job(&req).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("admission control") || msg.contains("memory budget exceeded"),
        "{msg}"
    );
    // sparse work still runs under the same tight budget
    let ok = c.run_job(&sparse_req("pwsgd", 16_384)).unwrap();
    assert_eq!(ok.densify_events, 0);
    let hd = c.run_job(&sparse_req("hdpwbatchsgd", 16_384)).unwrap();
    assert_eq!(hd.densify_events, 0, "implicit HD fits where dense cannot");
}

#[test]
fn admission_queues_until_headroom_appears() {
    // external pressure holds nearly the whole budget: the DENSE HD job
    // blocks in admission control (instead of charging into a failure)
    // until the pressure releases, then solves normally. Admission is the
    // queueing gate; the capability charge stays the hard enforcement.
    let budget = MemBudget::with_limit_mb(1);
    let hold = budget.try_charge((1 << 20) - 1024, "external-pressure").unwrap();
    let c = coord_with_budget(Arc::clone(&budget));
    let job = {
        let c = Arc::clone(&c);
        std::thread::spawn(move || {
            let mut req = sparse_req("hdpwbatchsgd", 1000);
            req.format = "dense".into();
            c.run_job(&req)
        })
    };
    // give the worker time to reach (and block in) the admission wait
    std::thread::sleep(std::time::Duration::from_millis(100));
    drop(hold); // headroom appears; the queued job proceeds
    let res = job.join().unwrap();
    assert!(res.is_ok(), "{:?}", res.err().map(|e| format!("{e:#}")));
    assert_eq!(budget.used(), 0);
    assert!(budget.peak() <= 1 << 20, "budget ceiling held throughout");
    // a job that can NEVER fit is rejected immediately, not queued
    let mut huge = sparse_req("hdpwbatchsgd", 16_384);
    huge.format = "dense".into();
    huge.time_budget = 30.0;
    let t0 = std::time::Instant::now();
    let err = c.run_job(&huge).unwrap_err();
    assert!(format!("{err:#}").contains("admission control"), "{err:#}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "impossible jobs must fail fast, not wait out their time budget"
    );
}

#[derive(Clone)]
struct VecWriter(Arc<Mutex<Vec<u8>>>);

impl Write for VecWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn over_budget_job_surfaces_as_error_line_on_the_serve_loop() {
    let budget = MemBudget::with_limit_mb(1);
    let c = coord_with_budget(budget);
    let out = Arc::new(Mutex::new(Vec::new()));
    let input = concat!(
        r#"{"solver":"hdpwbatchsgd","dataset":"syn2","n":16384,"format":"dense","time_budget":2,"reuse_precond":false}"#,
        "\n",
        r#"{"solver":"pwsgd","dataset":"syn2","n":1024,"format":"sparse","max_iters":50,"reuse_precond":false}"#,
        "\n"
    );
    server::handle_connection(&c, Cursor::new(input.to_string()), VecWriter(Arc::clone(&out)))
        .unwrap();
    let bytes = out.lock().unwrap().clone();
    let lines: Vec<Json> = String::from_utf8(bytes)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(lines.len(), 2);
    let err_line = lines
        .iter()
        .find(|j| j.get("error").is_some())
        .expect("over-budget job must produce an error line");
    let msg = err_line.get("error").and_then(Json::as_str).unwrap();
    assert!(
        msg.contains("admission control") || msg.contains("memory budget"),
        "{msg}"
    );
    // the in-budget sparse job on the same connection solved fine, with the
    // zero-densification accounting on its result line
    let ok_line = lines
        .iter()
        .find(|j| j.get("densify_events").is_some())
        .expect("solved job result line");
    assert_eq!(ok_line.get("densify_events").and_then(Json::as_f64), Some(0.0));
    assert_eq!(ok_line.get("sparse").and_then(Json::as_bool), Some(true));
}

#[test]
fn over_budget_inline_resketch_is_a_structured_job_error_with_id() {
    // the ISSUE 8 fallible-step criterion, end to end: IHS re-sketches
    // INSIDE the iteration loop. With SRHT pinned on a CSR dataset, each
    // re-sketch takes the whole-matrix scoped-densify fallback
    // (n*d doubles ~ 2.6 MiB), which a 1 MiB budget rejects — the MemError
    // propagates out of StepRule::step, through the driver and run_job, to
    // this connection's error line, with the request's id echoed back.
    // Admission can't catch it (IHS estimates 0: the charge is per-step and
    // transient), so this exercises the in-loop Result path specifically.
    let budget = MemBudget::with_limit_mb(1);
    let c = coord_with_budget(Arc::clone(&budget));
    let out = Arc::new(Mutex::new(Vec::new()));
    let input = concat!(
        r#"{"id":77,"solver":"ihs","dataset":"syn2","n":16384,"format":"sparse","sketch":"srht","max_iters":3,"time_budget":5,"reuse_precond":false}"#,
        "\n"
    );
    server::handle_connection(&c, Cursor::new(input.to_string()), VecWriter(Arc::clone(&out)))
        .unwrap();
    let bytes = out.lock().unwrap().clone();
    let text = String::from_utf8(bytes).unwrap();
    let line = Json::parse(text.lines().find(|l| !l.trim().is_empty()).unwrap()).unwrap();
    let msg = line
        .get("error")
        .and_then(Json::as_str)
        .expect("over-budget re-sketch must be an error line, not a result");
    assert!(msg.contains("memory budget exceeded"), "{msg}");
    assert_eq!(
        line.get("id").and_then(Json::as_f64),
        Some(77.0),
        "the error line must carry the request id"
    );
    assert_eq!(budget.used(), 0, "the failed charge left nothing behind");
    // the same request with the O(nnz) CountSketch re-sketch fits easily
    // and never densifies — the input-sparsity path the issue demands
    let mut ok = sparse_req("ihs", 16_384);
    ok.max_iters = 3;
    ok.sketch = "countsketch".into();
    let res = c.run_job(&ok).unwrap();
    assert_eq!(res.densify_events, 0, "CountSketch re-sketch is O(nnz)");
}
