//! Memory-budget acceptance suite (ISSUE 4).
//!
//! * A CSR dataset solved with a step-1-only solver (sgd / adagrad / svrg /
//!   pwsgd / ihs — plus pwgradient and the CGLS exact oracle) runs
//!   end-to-end through the coordinator with **zero** densifications and
//!   zero tracked bytes.
//! * An over-budget solve surfaces as a structured job error — through
//!   `run_job` and over the serve loop's wire — never a panic or an OOM.
//! * Admission control queues a job until headroom appears and rejects
//!   jobs that can never fit.
//! * HD solvers on CSR charge exactly the padded-buffer bytes and release
//!   them when the artifact is dropped.

use hdpw::backend::Backend;
use hdpw::coordinator::{server, Coordinator, CoordinatorConfig, JobRequest};
use hdpw::util::json::Json;
use hdpw::util::mem::MemBudget;
use std::io::{Cursor, Write};
use std::sync::{Arc, Mutex};

fn coord_with_budget(budget: Arc<MemBudget>) -> Arc<Coordinator> {
    Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers: 2,
            max_queue: 8,
            mem_budget: budget,
            ..CoordinatorConfig::default()
        },
    ))
}

fn sparse_req(solver: &str, n: usize) -> JobRequest {
    let mut req = JobRequest::default();
    req.dataset = "syn2".into();
    req.format = "sparse".into();
    req.density = 0.1;
    req.n = n;
    req.solver = solver.into();
    req.max_iters = 60;
    req.batch_size = 8;
    req.time_budget = 20.0;
    // pin the protocol knobs the CI env variants flip: with reuse on, a
    // cached artifact would (correctly) keep its HD bytes charged, which
    // is exactly what the used()==0 release assertions must not see
    req.reuse_precond = false;
    req.warm_start = false;
    req
}

#[test]
fn csr_step1_only_solvers_never_densify() {
    let budget = MemBudget::unlimited();
    let c = coord_with_budget(Arc::clone(&budget));
    for solver in ["sgd", "adagrad", "svrg", "pwsgd", "ihs", "pwgradient", "exact"] {
        let res = c.run_job(&sparse_req(solver, 1024)).unwrap();
        assert!(res.sparse, "{solver}: expected the CSR pipeline");
        assert_eq!(
            res.densify_events, 0,
            "{solver}: a step-1-only CSR solve must report densify_events == 0"
        );
        assert_eq!(res.mem_est_bytes, 0, "{solver}: step-1-only estimate");
    }
    assert_eq!(
        budget.densify_events(),
        0,
        "no stage on the step-1-only path may request a dense view"
    );
    assert_eq!(budget.peak(), 0, "zero tracked bytes end-to-end");
}

#[test]
fn hd_solver_on_csr_charges_only_the_padded_buffer() {
    let budget = MemBudget::unlimited();
    let c = coord_with_budget(Arc::clone(&budget));
    let res = c.run_job(&sparse_req("hdpwbatchsgd", 1000)).unwrap();
    let n_pad = 1000usize.next_power_of_two();
    let hd_bytes = n_pad * 21 * 8; // syn2: d = 20, +1 for the b column
    assert_eq!(res.mem_est_bytes, hd_bytes);
    assert_eq!(res.densify_events, 1, "exactly one HD materialization");
    assert_eq!(budget.peak(), hd_bytes, "peak == one padded buffer");
    // far below the dense-mirror footprint the old invariant forced
    // (mirror n*d + HD buffer would have been resident simultaneously)
    assert!(budget.peak() < 1000 * 20 * 8 + hd_bytes);
    assert_eq!(budget.used(), 0, "artifact dropped => bytes released");
}

#[test]
fn over_budget_job_is_an_error_not_a_panic() {
    // 1 MiB budget; hdpw on n=16384 x 20 needs ~2.75 MiB for the HD buffer
    let budget = MemBudget::with_limit_mb(1);
    let c = coord_with_budget(Arc::clone(&budget));
    let mut req = sparse_req("hdpwbatchsgd", 16_384);
    req.time_budget = 2.0;
    let err = c.run_job(&req).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("admission control") || msg.contains("memory budget exceeded"),
        "{msg}"
    );
    // step-1-only work still runs under the same tight budget
    let ok = c.run_job(&sparse_req("pwsgd", 16_384)).unwrap();
    assert_eq!(ok.densify_events, 0);
}

#[test]
fn admission_queues_until_headroom_appears() {
    // external pressure holds nearly the whole budget: the HD job blocks in
    // admission control (instead of charging into a failure) until the
    // pressure releases, then solves normally. Admission is the queueing
    // gate; the capability charge stays the hard enforcement.
    let budget = MemBudget::with_limit_mb(1);
    let hold = budget.try_charge((1 << 20) - 1024, "external-pressure").unwrap();
    let c = coord_with_budget(Arc::clone(&budget));
    let job = {
        let c = Arc::clone(&c);
        std::thread::spawn(move || c.run_job(&sparse_req("hdpwbatchsgd", 1000)))
    };
    // give the worker time to reach (and block in) the admission wait
    std::thread::sleep(std::time::Duration::from_millis(100));
    drop(hold); // headroom appears; the queued job proceeds
    let res = job.join().unwrap();
    assert!(res.is_ok(), "{:?}", res.err().map(|e| format!("{e:#}")));
    assert_eq!(budget.used(), 0);
    assert!(budget.peak() <= 1 << 20, "budget ceiling held throughout");
    // a job that can NEVER fit is rejected immediately, not queued
    let mut huge = sparse_req("hdpwbatchsgd", 16_384);
    huge.time_budget = 30.0;
    let t0 = std::time::Instant::now();
    let err = c.run_job(&huge).unwrap_err();
    assert!(format!("{err:#}").contains("admission control"), "{err:#}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "impossible jobs must fail fast, not wait out their time budget"
    );
}

#[derive(Clone)]
struct VecWriter(Arc<Mutex<Vec<u8>>>);

impl Write for VecWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn over_budget_job_surfaces_as_error_line_on_the_serve_loop() {
    let budget = MemBudget::with_limit_mb(1);
    let c = coord_with_budget(budget);
    let out = Arc::new(Mutex::new(Vec::new()));
    let input = concat!(
        r#"{"solver":"hdpwbatchsgd","dataset":"syn2","n":16384,"format":"sparse","time_budget":2,"reuse_precond":false}"#,
        "\n",
        r#"{"solver":"pwsgd","dataset":"syn2","n":1024,"format":"sparse","max_iters":50,"reuse_precond":false}"#,
        "\n"
    );
    server::handle_connection(&c, Cursor::new(input.to_string()), VecWriter(Arc::clone(&out)))
        .unwrap();
    let bytes = out.lock().unwrap().clone();
    let lines: Vec<Json> = String::from_utf8(bytes)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(lines.len(), 2);
    let err_line = lines
        .iter()
        .find(|j| j.get("error").is_some())
        .expect("over-budget job must produce an error line");
    let msg = err_line.get("error").and_then(Json::as_str).unwrap();
    assert!(
        msg.contains("admission control") || msg.contains("memory budget"),
        "{msg}"
    );
    // the in-budget sparse job on the same connection solved fine, with the
    // zero-densification accounting on its result line
    let ok_line = lines
        .iter()
        .find(|j| j.get("densify_events").is_some())
        .expect("solved job result line");
    assert_eq!(ok_line.get("densify_events").and_then(Json::as_f64), Some(0.0));
    assert_eq!(ok_line.get("sparse").and_then(Json::as_bool), Some(true));
}
