//! Full-stack end-to-end: coordinator + (PJRT when available) backend on the
//! canonical artifact shape, exercising the paper's evaluation protocol.

use hdpw::backend::Backend;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
use std::sync::Arc;

fn coordinator() -> (Arc<Coordinator>, bool) {
    let backend = Backend::auto();
    let pjrt = backend.has_pjrt();
    (
        Arc::new(Coordinator::new(backend, CoordinatorConfig::default())),
        pjrt,
    )
}

fn pjrt8k_job(solver: &str) -> JobRequest {
    let mut req = JobRequest::default();
    req.dataset = "pjrt8k".into();
    req.n = 8192;
    req.solver = solver.into();
    req.trials = 2;
    req.time_budget = 30.0;
    req
}

#[test]
fn pwgradient_through_full_stack_reaches_1e8() {
    let (coord, pjrt) = coordinator();
    let mut req = pjrt8k_job("pwgradient");
    req.max_iters = 300;
    req.target_rel_err = 1e-8;
    let res = coord.run_job(&req).unwrap();
    assert!(
        res.best_rel_err < 1e-8,
        "rel {} (pjrt={pjrt})",
        res.best_rel_err
    );
    if pjrt {
        assert!(
            coord.backend().pjrt_calls() > 0,
            "expected PJRT dispatches on the canonical shape"
        );
    }
}

#[test]
fn hdpw_batch_through_full_stack_constrained() {
    let (coord, _) = coordinator();
    for constraint in ["unc", "l1", "l2"] {
        let mut req = pjrt8k_job("hdpwbatchsgd");
        req.constraint = constraint.into();
        req.batch_size = 64;
        req.max_iters = 10_000;
        req.target_rel_err = 5e-2;
        let res = coord.run_job(&req).unwrap();
        assert!(
            res.best_rel_err < 0.5,
            "{constraint}: rel {}",
            res.best_rel_err
        );
    }
}

#[test]
fn acc_variant_through_full_stack() {
    let (coord, _) = coordinator();
    let mut req = pjrt8k_job("hdpwaccbatchsgd");
    req.batch_size = 64;
    req.max_iters = 10_000;
    req.target_rel_err = 1e-2;
    let res = coord.run_job(&req).unwrap();
    assert!(res.best_rel_err < 0.2, "rel {}", res.best_rel_err);
}

#[test]
fn pjrt_and_native_solvers_agree_statistically() {
    // Same job, same seeds, PJRT vs forced-native: identical sample indices
    // flow through bit-different but numerically-equivalent kernels; final
    // objectives must agree to solver tolerance.
    let (coord, pjrt) = coordinator();
    if !pjrt {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let native_coord = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig::default(),
    ));
    let mut req = pjrt8k_job("pwgradient");
    req.max_iters = 100;
    req.trials = 1;
    let a = coord.run_job(&req).unwrap();
    let b = native_coord.run_job(&req).unwrap();
    let denom = a.f_star.max(1e-300);
    assert!(
        ((a.best_f - b.best_f) / denom).abs() < 1e-9,
        "pjrt {} vs native {}",
        a.best_f,
        b.best_f
    );
}

#[test]
fn metrics_accumulate_across_jobs() {
    let (coord, _) = coordinator();
    let mut req = pjrt8k_job("exact");
    req.trials = 1;
    coord.run_job(&req).unwrap();
    coord.run_job(&req).unwrap();
    assert_eq!(
        coord
            .metrics
            .jobs_completed
            .load(std::sync::atomic::Ordering::Relaxed),
        2
    );
    assert!(coord.metrics.latency_percentile(50.0).is_some());
}
