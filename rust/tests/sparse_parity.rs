//! Dense/sparse parity — the CSR pipeline must reproduce the dense one.
//!
//! Acceptance (ISSUE 3): for every streaming sketch kind and several block
//! sizes, `S·[A|b]` and the resulting `R` computed from a CSR matrix must
//! match the densified equivalent within 1e-10; and one full solver trace
//! per family (pwsgd, ihs, svrg) on a seeded sparse dataset must track its
//! dense twin. Sketch outputs are compared at 1e-10 directly; solver traces
//! use a slightly relaxed relative bound (1e-8) because floating-point
//! re-association in the O(nnz) gradients compounds mildly over iterations
//! — the per-step perturbation is ~1e-15 relative.

use hdpw::backend::Backend;
use hdpw::data::sparse_gen::{generate_sparse, SparseSpec};
use hdpw::data::Dataset;
use hdpw::linalg::{qr, CsrMat, Mat};
use hdpw::precond::{precondition_csr_with, precondition_with};
use hdpw::sketch::{apply_streamed, apply_streamed_csr, SketchKind};
use hdpw::solvers::{by_name, SolverOpts};
use hdpw::util::rng::Rng;

const KINDS: [SketchKind; 4] = [
    SketchKind::CountSketch,
    SketchKind::SparseEmbed,
    SketchKind::Gaussian,
    SketchKind::Srht,
];

fn sparse_ds(n: usize, d: usize, density: f64, seed: u64) -> Dataset {
    generate_sparse(
        &SparseSpec {
            name: "parity".into(),
            n,
            d,
            density,
            kappa: 1e3,
            noise: 0.1,
            signal_scale: 1.0,
        },
        &mut Rng::new(seed),
    )
}

/// The same data re-homed in the dense representation — the dense twin.
fn dense_twin(ds: &Dataset) -> Dataset {
    Dataset::dense(
        ds.name.clone(),
        ds.dense_clone(),
        ds.b.clone(),
        ds.x_star_planted.clone(),
    )
}

#[test]
fn sketched_aug_and_r_match_densified_within_1e10() {
    let d = 7;
    let s = 48;
    for n in [333usize, 512] {
        let ds = sparse_ds(n, d, 0.3, 1000 + n as u64);
        // packed [A | b]: the sketch target of Algorithm 1's augmented form
        let bmat = Mat::from_vec(n, 1, ds.b.clone());
        let aug_dense = ds.dense_clone().hstack(&bmat);
        let aug_csr = CsrMat::from_dense(&aug_dense);
        for kind in KINDS {
            // identical rng stream for the dense reference and the CSR run
            let mut r1 = Rng::new(7 * n as u64 + 1);
            let sk_dense = kind.build(s, n, &mut r1);
            let want_sa = sk_dense.apply(&aug_dense);
            let want_r = qr::qr_r(&want_sa);
            for block_nnz in [1usize, 16, 300, 1 << 20] {
                for threads in [1usize, 4] {
                    let mut r2 = Rng::new(7 * n as u64 + 1);
                    let sk = kind.build(s, n, &mut r2);
                    let (sa, shards) =
                        apply_streamed_csr(sk.as_ref(), &aug_csr, Some(block_nnz), threads);
                    assert_eq!((sa.rows, sa.cols), (s, d + 1));
                    let diff = sa.max_abs_diff(&want_sa);
                    assert!(
                        diff < 1e-10,
                        "{} n={n} block_nnz={block_nnz} threads={threads}: S[A|b] diff {diff}",
                        kind.name()
                    );
                    let r = qr::qr_r(&sa);
                    let rdiff = r.max_abs_diff(&want_r);
                    assert!(
                        rdiff < 1e-10,
                        "{} n={n} block_nnz={block_nnz} threads={threads}: R diff {rdiff}",
                        kind.name()
                    );
                    if kind == SketchKind::Srht {
                        assert_eq!(shards, 1, "SRHT keeps the densify fallback");
                    } else if block_nnz < aug_csr.nnz() {
                        assert!(
                            shards > 1,
                            "{} block_nnz={block_nnz}: expected nnz shards",
                            kind.name()
                        );
                    }
                }
            }
            // the dense streamed pipeline agrees too (same sketch sample)
            let mut r3 = Rng::new(7 * n as u64 + 1);
            let sk = kind.build(s, n, &mut r3);
            let (sa_dense_stream, _) = apply_streamed(sk.as_ref(), &aug_dense, Some(64), 4);
            assert!(sa_dense_stream.max_abs_diff(&want_sa) < 1e-10, "{}", kind.name());
        }
    }
}

#[test]
fn precondition_r_matches_across_representations() {
    let ds = sparse_ds(1024, 10, 0.2, 9);
    let dense_a = ds.dense_clone();
    let be = Backend::native_with(4, None);
    for kind in KINDS {
        let mut r1 = Rng::new(42);
        let p_dense = precondition_with(&be, &dense_a, kind, 300, &mut r1, Some(128));
        let mut r2 = Rng::new(42);
        let csr = ds.csr().unwrap();
        let p_csr = precondition_csr_with(&be, csr, kind, 300, &mut r2, Some(128));
        let rdiff = p_csr.r.max_abs_diff(&p_dense.r);
        assert!(rdiff < 1e-10, "{}: R diff {rdiff}", kind.name());
        assert_eq!(p_csr.sketch_rows, 300);
    }
}

/// One full solver trace per family on a seeded sparse dataset: same seed,
/// same data, CSR vs dense representation. Iteration counts and trace
/// shapes must be identical (sampling consumes the rng identically); the
/// objective values track within the re-association bound.
#[test]
fn solver_traces_track_across_representations() {
    let ds_sparse = sparse_ds(2048, 8, 0.25, 77);
    let ds_dense = dense_twin(&ds_sparse);
    for (solver, max_iters, chunk) in [
        ("pwsgd", 300usize, 100usize), // leverage-score weighted SGD family
        ("ihs", 15, 1),                // fresh-sketch-per-iteration family
        ("svrg", 300, 100),            // variance-reduced family
        ("pwgradient", 30, 2),         // frozen-sketch full-gradient family
    ] {
        let mut opts = SolverOpts::default();
        opts.batch_size = 8;
        opts.max_iters = max_iters;
        opts.chunk = chunk;
        opts.time_budget = 1e9; // determinism: stop on iterations only
        opts.seed = 5;
        let s = by_name(solver).unwrap();
        let rep_sparse = s.solve(&Backend::native(), &ds_sparse, &opts).unwrap();
        let rep_dense = s.solve(&Backend::native(), &ds_dense, &opts).unwrap();
        assert!(
            ds_sparse.dense_if_ready().is_none(),
            "{solver}: a step-1-only sparse solve must never materialize a dense view"
        );
        assert_eq!(
            rep_sparse.iters, rep_dense.iters,
            "{solver}: iteration counts must match"
        );
        assert_eq!(
            rep_sparse.trace.len(),
            rep_dense.trace.len(),
            "{solver}: trace shapes must match"
        );
        for (k, (ps, pd)) in rep_sparse
            .trace
            .iter()
            .zip(&rep_dense.trace)
            .enumerate()
        {
            assert_eq!(ps.iters, pd.iters, "{solver}: trace[{k}].iters");
            let tol = 1e-8 * (1.0 + pd.f.abs());
            assert!(
                (ps.f - pd.f).abs() <= tol,
                "{solver}: trace[{k}] f diverged: sparse {} vs dense {}",
                ps.f,
                pd.f
            );
        }
        let tol = 1e-8 * (1.0 + rep_dense.f_final.abs());
        assert!(
            (rep_sparse.f_final - rep_dense.f_final).abs() <= tol,
            "{solver}: f_final sparse {} vs dense {}",
            rep_sparse.f_final,
            rep_dense.f_final
        );
    }
}

/// The dense twin must take *exactly* the pre-sparse code path: a dense
/// dataset run twice replays bitwise (guards against the representation
/// dispatch accidentally perturbing dense numerics).
#[test]
fn dense_twin_replays_bitwise() {
    let ds = dense_twin(&sparse_ds(1024, 8, 0.25, 99));
    for solver in ["pwsgd", "ihs", "svrg", "sgd", "adagrad"] {
        let mut opts = SolverOpts::default();
        opts.batch_size = 8;
        opts.max_iters = if solver == "ihs" { 10 } else { 200 };
        opts.chunk = if solver == "ihs" { 1 } else { 100 };
        opts.time_budget = 1e9;
        let s = by_name(solver).unwrap();
        let r1 = s.solve(&Backend::native(), &ds, &opts).unwrap();
        let r2 = s.solve(&Backend::native(), &ds, &opts).unwrap();
        assert_eq!(r1.x, r2.x, "{solver}");
        assert_eq!(r1.f_final.to_bits(), r2.f_final.to_bits(), "{solver}");
    }
}
