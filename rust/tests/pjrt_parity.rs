//! Integration: the PJRT artifact path and the native path must agree.
//!
//! Requires `make artifacts` (skipped with a notice otherwise). Every op in
//! the manifest is exercised at its canonical shape with random inputs and
//! compared against the native implementation to f64 tolerance.

use hdpw::backend::Backend;
use hdpw::constraints::{l1_ball, l2_ball, unconstrained, ConstraintSet};
use hdpw::linalg::{blas, qr, tri, Mat};
use hdpw::runtime::{Engine, EngineHandle};
use hdpw::util::rng::Rng;

fn engine() -> Option<EngineHandle> {
    match EngineHandle::spawn(&Engine::default_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP pjrt parity tests: {err:#}");
            None
        }
    }
}

struct Setup {
    pjrt: Backend,
    native: Backend,
    n: usize,
    d: usize,
    rs: Vec<usize>,
    chunk_t: usize,
    pw_t: usize,
    a: Mat,
    b: Vec<f64>,
    pinv: Mat,
    rng: Rng,
}

fn setup() -> Option<Setup> {
    let e = engine()?;
    let meta = e.meta().clone();
    let mut rng = Rng::new(2024);
    let a = Mat::gaussian(meta.n, meta.d, &mut rng);
    let xt = rng.gaussians(meta.d);
    let mut b = blas::gemv(&a, &xt);
    for v in &mut b {
        *v += 0.1 * rng.gaussian();
    }
    let r = qr::qr_r(&a);
    let pinv = tri::pinv_dense(&r);
    Some(Setup {
        pjrt: Backend::with_engine(e.clone()),
        native: Backend::native(),
        n: meta.n,
        d: meta.d,
        rs: meta.rs,
        chunk_t: meta.chunk_t,
        pw_t: meta.pw_t,
        a,
        b,
        pinv,
        rng,
    })
}

fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let scale = 1.0 + a.iter().map(|v| v.abs()).fold(0.0, f64::max);
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: pjrt {x} vs native {y} (tol {tol}, scale {scale})"
        );
    }
}

#[test]
fn manifest_has_expected_ops() {
    let Some(e) = engine() else { return };
    let names = e.op_names();
    assert!(names.iter().any(|n| n.starts_with("hd_transform")));
    assert!(names.iter().any(|n| n.starts_with("batch_grad")));
    assert!(names.iter().any(|n| n.starts_with("sgd_chunk_unc")));
    assert!(names.iter().any(|n| n.starts_with("acc_chunk_l1")));
    assert!(names.iter().any(|n| n.starts_with("pw_gradient_chunk_l2")));
    assert!(e.meta().n > 0 && e.meta().d > 0);
}

#[test]
fn hd_transform_parity() {
    let Some(mut s) = setup() else { return };
    let bmat = Mat::from_vec(s.n, 1, s.b.clone());
    let aug = s.a.hstack(&bmat);
    let signs = s.rng.signs(s.n);
    let got = s.pjrt.hd_transform(&aug, &signs);
    let want = s.native.hd_transform(&aug, &signs);
    assert!(s.pjrt.pjrt_calls() == 1, "did not dispatch to PJRT");
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-9, "hd_transform diff {diff}");
}

#[test]
fn batch_grad_parity_all_r() {
    let Some(mut s) = setup() else { return };
    for &r in &s.rs {
        let idx = s.rng.indices(r, s.n);
        let m = s.a.gather_rows(&idx);
        let v: Vec<f64> = idx.iter().map(|&i| s.b[i]).collect();
        let x = s.rng.gaussians(s.d);
        let scale = 2.0 * s.n as f64 / r as f64;
        let got = s.pjrt.batch_grad(&m, &v, &x, scale);
        let want = s.native.batch_grad(&m, &v, &x, scale);
        assert_close(&got, &want, 1e-9, &format!("batch_grad r={r}"));
    }
}

#[test]
fn full_grad_and_residual_parity() {
    let Some(mut s) = setup() else { return };
    let x = s.rng.gaussians(s.d);
    let got = s.pjrt.full_grad(&s.a, &s.b, &x);
    let want = s.native.full_grad(&s.a, &s.b, &x);
    assert_close(&got, &want, 1e-9, "full_grad");
    let fp = s.pjrt.residual_sq(&s.a, &s.b, &x);
    let fnat = s.native.residual_sq(&s.a, &s.b, &x);
    assert!(
        (fp - fnat).abs() < 1e-9 * (1.0 + fnat),
        "residual_sq {fp} vs {fnat}"
    );
}

#[test]
fn gd_step_parity_all_constraints() {
    let Some(mut s) = setup() else { return };
    let x = s.rng.gaussians(s.d);
    let g = s.rng.gaussians(s.d);
    for cons in [unconstrained(), l2_ball(0.7), l1_ball(0.9)] {
        let got = s.pjrt.gd_step(&x, &s.pinv, &g, 0.5, cons.as_ref(), None);
        let want = s.native.gd_step(&x, &s.pinv, &g, 0.5, cons.as_ref(), None);
        assert_close(&got, &want, 1e-9, &format!("gd_step {}", cons.tag()));
        assert!(cons.contains(&got, 1e-9));
    }
}

#[test]
fn sgd_chunk_parity_all_constraints() {
    let Some(mut s) = setup() else { return };
    let r = s.rs[s.rs.len() / 2];
    let idx: Vec<Vec<usize>> = (0..s.chunk_t).map(|_| s.rng.indices(r, s.n)).collect();
    let x0 = s.rng.gaussians(s.d);
    let scale = 2.0 * s.n as f64 / r as f64;
    for cons in [unconstrained(), l2_ball(1.0), l1_ball(2.0)] {
        let (xt_p, xs_p) = s.pjrt.sgd_chunk(
            &s.a, &s.b, &x0, &s.pinv, &idx, 0.1, scale, cons.as_ref(), None,
        );
        let (xt_n, xs_n) = s.native.sgd_chunk(
            &s.a, &s.b, &x0, &s.pinv, &idx, 0.1, scale, cons.as_ref(), None,
        );
        assert_close(&xt_p, &xt_n, 1e-8, &format!("sgd_chunk x {}", cons.tag()));
        assert_close(&xs_p, &xs_n, 1e-8, &format!("sgd_chunk xsum {}", cons.tag()));
    }
}

#[test]
fn acc_chunk_parity() {
    let Some(mut s) = setup() else { return };
    // acc artifacts exist only for the middle r (see aot.py)
    let r = s.rs[s.rs.len() / 2];
    let t = s.chunk_t;
    let idx: Vec<Vec<usize>> = (0..t).map(|_| s.rng.indices(r, s.n)).collect();
    let alphas: Vec<f64> = (1..=t).map(|k| 2.0 / (k as f64 + 1.0)).collect();
    let qs = alphas.clone();
    let etas = vec![0.05; t];
    let x0 = s.rng.gaussians(s.d);
    let xhat0 = x0.clone();
    let scale = 2.0 * s.n as f64 / r as f64;
    for cons in [unconstrained(), l2_ball(1.0), l1_ball(2.0)] {
        let (x_p, xh_p) = s.pjrt.acc_chunk(
            &s.a,
            &s.b,
            &x0,
            &xhat0,
            &s.pinv,
            &idx,
            &alphas,
            &qs,
            &etas,
            2.0,
            scale,
            cons.as_ref(),
            None,
        );
        let (x_n, xh_n) = s.native.acc_chunk(
            &s.a,
            &s.b,
            &x0,
            &xhat0,
            &s.pinv,
            &idx,
            &alphas,
            &qs,
            &etas,
            2.0,
            scale,
            cons.as_ref(),
            None,
        );
        assert_close(&x_p, &x_n, 1e-8, &format!("acc_chunk x {}", cons.tag()));
        assert_close(&xh_p, &xh_n, 1e-8, &format!("acc_chunk xhat {}", cons.tag()));
    }
}

#[test]
fn pw_gradient_chunk_parity_and_convergence() {
    let Some(s) = setup() else { return };
    let x0 = vec![0.0; s.d];
    for cons in [unconstrained(), l2_ball(0.5), l1_ball(1.0)] {
        let got = s.pjrt.pw_gradient_chunk(
            &s.a, &s.b, &x0, &s.pinv, 0.5, s.pw_t, cons.as_ref(), None,
        );
        let want = s.native.pw_gradient_chunk(
            &s.a, &s.b, &x0, &s.pinv, 0.5, s.pw_t, cons.as_ref(), None,
        );
        assert_close(&got, &want, 1e-8, &format!("pw_gradient {}", cons.tag()));
    }
    // exact pinv + eta=1/2: unconstrained solution == least squares optimum
    let xt = s.pjrt.pw_gradient_chunk(
        &s.a,
        &s.b,
        &x0,
        &s.pinv,
        0.5,
        s.pw_t,
        &hdpw::constraints::Unconstrained,
        None,
    );
    let xstar = qr::lstsq(&s.a, &s.b);
    assert_close(&xt, &xstar, 1e-7, "pwGradient vs exact");
}

#[test]
fn dispatch_falls_back_on_shape_mismatch() {
    let Some(e) = engine() else { return };
    let be = Backend::with_engine(e);
    let mut rng = Rng::new(1);
    // off-manifest shape: must fall back to native without error
    let a = Mat::gaussian(100, 7, &mut rng);
    let b = rng.gaussians(100);
    let x = rng.gaussians(7);
    let _ = be.full_grad(&a, &b, &x);
    assert_eq!(be.pjrt_calls(), 0);
    assert_eq!(be.native_calls(), 1);
}
