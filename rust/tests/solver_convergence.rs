//! Cross-solver integration: every solver against every constraint on a
//! shared ill-conditioned dataset, plus the paper's qualitative orderings.

use hdpw::backend::Backend;
use hdpw::data::synthetic::{generate, SynSpec};
use hdpw::data::Dataset;
use hdpw::prox::Constraint;
use hdpw::solvers::exact::ground_truth;
use hdpw::solvers::{by_name, SolverOpts};
use hdpw::util::rng::Rng;

fn dataset(kappa: f64) -> Dataset {
    let spec = SynSpec {
        name: "it".into(),
        n: 4096,
        d: 12,
        kappa,
        noise: 1.0,
        signal_scale: SynSpec::signal_auto(4096),
    };
    generate(&spec, &mut Rng::new(99))
}

#[test]
fn every_solver_improves_every_constraint() {
    let ds = dataset(1e4);
    let gt = ground_truth(&ds);
    let be = Backend::native();
    for solver_name in [
        "hdpwbatchsgd",
        "hdpwaccbatchsgd",
        "pwgradient",
        "ihs",
        "pwsgd",
        "sgd",
        "adagrad",
        "svrg",
        "pwsvrg",
    ] {
        for (cons, tag) in [
            (Constraint::Unconstrained, "unc"),
            (Constraint::L1Ball { radius: gt.l1_radius }, "l1"),
            (Constraint::L2Ball { radius: gt.l2_radius }, "l2"),
        ] {
            let solver = by_name(solver_name).unwrap();
            let mut opts = SolverOpts::default();
            opts.constraint = cons;
            opts.batch_size = 32;
            opts.max_iters = match solver_name {
                "pwgradient" | "ihs" => 100,
                _ => 3000,
            };
            opts.time_budget = 30.0;
            opts.chunk = 100;
            let rep = solver.solve(&be, &ds, &opts).unwrap();
            let rel0 = (rep.trace[0].f - gt.f_star) / gt.f_star;
            let rel = (rep.f_final - gt.f_star) / gt.f_star;
            // every solver must improve substantially from x0 = 0...
            assert!(
                rel < 0.5 * rel0,
                "{solver_name}/{tag}: rel {rel:.3e} vs initial {rel0:.3e}"
            );
            // ...and respect its constraint
            assert!(cons.contains(&rep.x, 1e-6), "{solver_name}/{tag} infeasible");
        }
    }
}

#[test]
fn preconditioned_methods_dominate_on_severe_conditioning() {
    // kappa = 1e8 (the paper's Syn1/Buzz regime): plain SGD/Adagrad stall,
    // HDpw/pw methods do not — the qualitative content of Figs 2/4/6.
    let ds = dataset(1e8);
    let gt = ground_truth(&ds);
    let be = Backend::native();
    let run = |name: &str, iters: usize| {
        let solver = by_name(name).unwrap();
        let mut opts = SolverOpts::default();
        opts.batch_size = 32;
        opts.max_iters = iters;
        opts.chunk = 200;
        opts.time_budget = 60.0;
        let rep = solver.solve(&be, &ds, &opts).unwrap();
        (rep.f_final - gt.f_star) / gt.f_star.max(1e-300)
    };
    let hdpw = run("hdpwbatchsgd", 4000);
    let sgd = run("sgd", 4000);
    let pwg = run("pwgradient", 60);
    assert!(hdpw < 0.1, "hdpw rel {hdpw}");
    assert!(pwg < 1e-8, "pwgradient rel {pwg}");
    assert!(
        sgd > 10.0 * hdpw.max(1e-6),
        "sgd ({sgd}) should stall vs hdpw ({hdpw}) at kappa=1e8"
    );
}

#[test]
fn pw_gradient_beats_ihs_wall_clock_same_accuracy() {
    // The high-precision headline: one sketch beats re-sketching.
    let ds = dataset(1e6);
    let gt = ground_truth(&ds);
    let be = Backend::native();
    let time_to = |name: &str| {
        let solver = by_name(name).unwrap();
        let mut opts = SolverOpts::default();
        opts.max_iters = 200;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(1e-8 * gt.f_star);
        opts.time_budget = 60.0;
        let rep = solver.solve(&be, &ds, &opts).unwrap();
        rep.time_to_rel_err(gt.f_star, 1e-8)
            .unwrap_or(f64::INFINITY)
    };
    let pw = time_to("pwgradient");
    let ihs = time_to("ihs");
    assert!(pw.is_finite(), "pwgradient never reached 1e-8");
    assert!(ihs.is_finite(), "ihs never reached 1e-8");
    assert!(
        pw < ihs,
        "pwGradient ({pw:.4}s) should beat IHS ({ihs:.4}s) to 1e-8"
    );
}

#[test]
fn trials_protocol_is_deterministic_per_seed() {
    let ds = dataset(1e3);
    let be = Backend::native();
    let solver = by_name("hdpwbatchsgd").unwrap();
    let mut opts = SolverOpts::default();
    opts.max_iters = 500;
    opts.chunk = 100;
    opts.seed = 33;
    let a = solver.solve(&be, &ds, &opts).unwrap();
    let b = solver.solve(&be, &ds, &opts).unwrap();
    assert_eq!(a.x, b.x);
    opts.seed = 34;
    let c = solver.solve(&be, &ds, &opts).unwrap();
    assert_ne!(a.x, c.x);
}
