//! Cross-solver integration: every solver against every constraint on a
//! shared ill-conditioned dataset, plus the paper's qualitative orderings.

use hdpw::backend::Backend;
use hdpw::constraints::{
    self, affine_eq, coord_box, elastic_net, l1_ball, l2_ball, nonneg, simplex, unconstrained,
    ConstraintSet,
};
use hdpw::data::synthetic::{generate, SynSpec};
use hdpw::data::Dataset;
use hdpw::linalg::{blas, Mat};
use hdpw::solvers::exact::ground_truth;
use hdpw::solvers::{by_name, SolverOpts};
use hdpw::util::rng::Rng;

fn dataset(kappa: f64) -> Dataset {
    let spec = SynSpec {
        name: "it".into(),
        n: 4096,
        d: 12,
        kappa,
        noise: 1.0,
        signal_scale: SynSpec::signal_auto(4096),
    };
    generate(&spec, &mut Rng::new(99))
}

#[test]
fn every_solver_improves_every_constraint() {
    let ds = dataset(1e4);
    let gt = ground_truth(&ds);
    let be = Backend::native();
    for solver_name in [
        "hdpwbatchsgd",
        "hdpwaccbatchsgd",
        "pwgradient",
        "ihs",
        "pwsgd",
        "sgd",
        "adagrad",
        "svrg",
        "pwsvrg",
    ] {
        for (cons, tag) in [
            (unconstrained(), "unc"),
            (l1_ball(gt.l1_radius), "l1"),
            (l2_ball(gt.l2_radius), "l2"),
        ] {
            let solver = by_name(solver_name).unwrap();
            let mut opts = SolverOpts::default();
            opts.constraint = cons.clone();
            opts.batch_size = 32;
            opts.max_iters = match solver_name {
                "pwgradient" | "ihs" => 100,
                _ => 3000,
            };
            opts.time_budget = 30.0;
            opts.chunk = 100;
            let rep = solver.solve(&be, &ds, &opts).unwrap();
            let rel0 = (rep.trace[0].f - gt.f_star) / gt.f_star;
            let rel = (rep.f_final - gt.f_star) / gt.f_star;
            // every solver must improve substantially from x0 = 0...
            assert!(
                rel < 0.5 * rel0,
                "{solver_name}/{tag}: rel {rel:.3e} vs initial {rel0:.3e}"
            );
            // ...and respect its constraint
            assert!(cons.contains(&rep.x, 1e-6), "{solver_name}/{tag} infeasible");
        }
    }
}

#[test]
fn preconditioned_methods_dominate_on_severe_conditioning() {
    // kappa = 1e8 (the paper's Syn1/Buzz regime): plain SGD/Adagrad stall,
    // HDpw/pw methods do not — the qualitative content of Figs 2/4/6.
    let ds = dataset(1e8);
    let gt = ground_truth(&ds);
    let be = Backend::native();
    let run = |name: &str, iters: usize| {
        let solver = by_name(name).unwrap();
        let mut opts = SolverOpts::default();
        opts.batch_size = 32;
        opts.max_iters = iters;
        opts.chunk = 200;
        opts.time_budget = 60.0;
        let rep = solver.solve(&be, &ds, &opts).unwrap();
        (rep.f_final - gt.f_star) / gt.f_star.max(1e-300)
    };
    let hdpw = run("hdpwbatchsgd", 4000);
    let sgd = run("sgd", 4000);
    let pwg = run("pwgradient", 60);
    assert!(hdpw < 0.1, "hdpw rel {hdpw}");
    assert!(pwg < 1e-8, "pwgradient rel {pwg}");
    assert!(
        sgd > 10.0 * hdpw.max(1e-6),
        "sgd ({sgd}) should stall vs hdpw ({hdpw}) at kappa=1e8"
    );
}

#[test]
fn pw_gradient_beats_ihs_wall_clock_same_accuracy() {
    // The high-precision headline: one sketch beats re-sketching.
    let ds = dataset(1e6);
    let gt = ground_truth(&ds);
    let be = Backend::native();
    let time_to = |name: &str| {
        let solver = by_name(name).unwrap();
        let mut opts = SolverOpts::default();
        opts.max_iters = 200;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(1e-8 * gt.f_star);
        opts.time_budget = 60.0;
        let rep = solver.solve(&be, &ds, &opts).unwrap();
        rep.time_to_rel_err(gt.f_star, 1e-8)
            .unwrap_or(f64::INFINITY)
    };
    let pw = time_to("pwgradient");
    let ihs = time_to("ihs");
    assert!(pw.is_finite(), "pwgradient never reached 1e-8");
    assert!(ihs.is_finite(), "ihs never reached 1e-8");
    assert!(
        pw < ihs,
        "pwGradient ({pw:.4}s) should beat IHS ({ihs:.4}s) to 1e-8"
    );
}

#[test]
fn trials_protocol_is_deterministic_per_seed() {
    let ds = dataset(1e3);
    let be = Backend::native();
    let solver = by_name("hdpwbatchsgd").unwrap();
    let mut opts = SolverOpts::default();
    opts.max_iters = 500;
    opts.chunk = 100;
    opts.seed = 33;
    let a = solver.solve(&be, &ds, &opts).unwrap();
    let b = solver.solve(&be, &ds, &opts).unwrap();
    assert_eq!(a.x, b.x);
    opts.seed = 34;
    let c = solver.solve(&be, &ds, &opts).unwrap();
    assert_ne!(a.x, c.x);
}


/// A fixture whose planted solution sits on (or within a hair of) EVERY
/// new constraint set: xt is positive and sums to 1, so the unconstrained
/// optimum is simplex/nonneg/box/enet/affine-feasible up to the small
/// noise perturbation, and the constrained optima all but coincide with
/// the unconstrained one.
fn simplex_fixture(n: usize, d: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let a = Mat::gaussian(n, d, &mut rng);
    let mut xt: Vec<f64> = (0..d).map(|_| 0.5 + rng.uniform()).collect();
    let total: f64 = xt.iter().sum();
    for v in &mut xt {
        *v /= total;
    }
    let mut b = blas::gemv(&a, &xt);
    for v in &mut b {
        *v += noise * rng.gaussian();
    }
    Dataset::dense("simplexfix", a, b, Some(xt))
}

#[test]
fn every_solver_stays_feasible_on_the_new_sets() {
    let ds = simplex_fixture(1024, 8, 0.01, 7);
    let gt = ground_truth(&ds);
    let be = Backend::native();
    let enet_alpha = 0.5;
    let enet_radius =
        enet_alpha * gt.l1_radius + 0.5 * (1.0 - enet_alpha) * gt.l2_radius * gt.l2_radius;
    let sets: Vec<hdpw::ConstraintRef> = vec![
        nonneg(),
        simplex(1.0),
        coord_box(vec![0.0; 8], vec![1.0; 8]),
        elastic_net(enet_alpha, enet_radius),
        affine_eq(
            Mat::from_fn(1, 8, |_, _| 1.0),
            vec![gt.x_star.iter().sum::<f64>()],
        )
        .unwrap(),
    ];
    for solver_name in [
        "hdpwbatchsgd",
        "hdpwaccbatchsgd",
        "pwgradient",
        "ihs",
        "pwsgd",
        "sgd",
        "adagrad",
        "svrg",
        "pwsvrg",
    ] {
        for cons in &sets {
            let solver = by_name(solver_name).unwrap();
            let mut opts = SolverOpts::default();
            opts.constraint = cons.clone();
            opts.batch_size = 32;
            opts.max_iters = match solver_name {
                "pwgradient" | "ihs" => 80,
                _ => 1500,
            };
            opts.chunk = 100;
            opts.time_budget = 30.0;
            let rep = solver.solve(&be, &ds, &opts).unwrap();
            assert!(
                cons.contains(&rep.x, 1e-6),
                "{solver_name}/{} infeasible: {:?}",
                cons.tag(),
                rep.x
            );
            let rel0 = (rep.trace[0].f - gt.f_star) / gt.f_star;
            let rel = (rep.f_final - gt.f_star) / gt.f_star;
            assert!(
                rel < 0.5 * rel0,
                "{solver_name}/{}: rel {rel:.3e} vs initial {rel0:.3e}",
                cons.tag()
            );
        }
    }
}

#[test]
fn pwsgd_reaches_constrained_optimum_under_simplex_and_nonneg() {
    // ISSUE-5 acceptance: pwSGD under simplex + nonneg converges to the
    // constrained optimum — rel err vs the `exact` oracle <= 1e-3 within
    // the paper's iteration budgets. The fixture plants the solution on
    // the simplex with small noise, so the constrained and unconstrained
    // optima agree to O(1/n) relative error and `exact` is a valid
    // reference for both sets.
    let ds = simplex_fixture(2048, 6, 1e-3, 11);
    let gt = ground_truth(&ds);
    let be = Backend::native();
    for cons in [simplex(1.0), nonneg()] {
        let mut opts = SolverOpts::default();
        opts.constraint = cons.clone();
        opts.batch_size = 8;
        opts.max_iters = 20_000;
        opts.chunk = 500;
        opts.time_budget = 60.0;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(5e-4 * gt.f_star);
        let rep = by_name("pwsgd").unwrap().solve(&be, &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(
            rel <= 1e-3,
            "pwsgd/{}: rel {rel:.3e} after {} iters",
            cons.tag(),
            rep.iters
        );
        assert!(cons.contains(&rep.x, 1e-9), "{} infeasible", cons.tag());
    }
}

#[test]
fn diameter_aware_theory_steps_cover_the_new_sets() {
    // Theorem-2 step sizes use the constraint diameter where the paper
    // defines one; the new bounded sets must report one, the unbounded
    // ones must not (falling back to the f0 surrogate).
    assert!(simplex(1.0).diameter().is_some());
    assert!(elastic_net(0.5, 1.0).diameter().is_some());
    assert!(coord_box(vec![-1.0; 4], vec![1.0; 4]).diameter().is_some());
    assert!(nonneg().diameter().is_none());
    assert!(affine_eq(Mat::from_fn(1, 4, |_, _| 1.0), vec![1.0])
        .unwrap()
        .diameter()
        .is_none());
    // and the legacy values are unchanged
    assert_eq!(l2_ball(2.0).diameter(), Some(2.0 / 2f64.sqrt()));
    assert_eq!(
        constraints::scalar_box(-1.0, 3.0).diameter(),
        Some(3.0 / 2f64.sqrt())
    );
}
