//! Integration tests for the preconditioner artifact cache and the unified
//! solve-session driver (ISSUE 2 acceptance criteria):
//!
//! 1. determinism regression: two `run_job` calls with an identical
//!    `JobRequest` (same seed, trials = 3) produce bitwise-equal `x` and
//!    traces — trial-seed forking and the cache leak no state across runs;
//! 2. with `reuse_precond = true`, a second identical job reports a cache
//!    hit and a collapsed `setup_secs`;
//! 3. the default path (`reuse_precond = false`) never touches the cache
//!    and is bit-reproducible for every solver in the registry.

use hdpw::backend::Backend;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest, JobResult};
use hdpw::precond::CacheOutcome;
use std::sync::Arc;

fn coordinator() -> Arc<Coordinator> {
    Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig::default(),
    ))
}

fn base_req(solver: &str, n: usize, max_iters: usize) -> JobRequest {
    let mut req = JobRequest::default();
    req.dataset = "syn2".into();
    req.n = n;
    req.solver = solver.into();
    req.max_iters = max_iters;
    req.batch_size = 16;
    // determinism requires stopping on iteration count, never wall clock
    req.time_budget = 1e9;
    req.seed = 42;
    req.trials = 1;
    // explicit: the CI env variant (HDPW_REUSE_PRECOND=1) flips the default
    req.reuse_precond = false;
    req.warm_start = false;
    req
}

/// Bitwise comparison of everything deterministic in a result (trace `secs`
/// are wall clock and excluded by definition).
fn assert_bitwise_equal(a: &JobResult, b: &JobResult, tag: &str) {
    assert_eq!(a.best.x, b.best.x, "{tag}: best x differs");
    assert_eq!(a.best_f.to_bits(), b.best_f.to_bits(), "{tag}: best f differs");
    assert_eq!(a.best.iters, b.best.iters, "{tag}: iteration count differs");
    assert_eq!(a.best.trace.len(), b.best.trace.len(), "{tag}: trace length differs");
    for (i, (p, q)) in a.best.trace.iter().zip(&b.best.trace).enumerate() {
        assert_eq!(p.iters, q.iters, "{tag}: trace[{i}].iters differs");
        assert_eq!(
            p.f.to_bits(),
            q.f.to_bits(),
            "{tag}: trace[{i}].f differs ({} vs {})",
            p.f,
            q.f
        );
    }
}

#[test]
fn determinism_regression_trials3_default_path() {
    // satellite: identical JobRequests (seed fixed, trials = 3) must replay
    // bit-identically — proves trial-seed forking leaks no state.
    let c = coordinator();
    for solver in ["hdpwbatchsgd", "pwgradient", "sgd"] {
        let mut req = base_req(solver, 2048, 300);
        req.trials = 3;
        let r1 = c.run_job(&req).unwrap();
        let r2 = c.run_job(&req).unwrap();
        assert_bitwise_equal(&r1, &r2, solver);
        assert_eq!(r1.trials_run, 3);
    }
}

#[test]
fn determinism_regression_trials3_with_cache() {
    // same request twice with reuse on: run 1 populates the cache, run 2
    // hits it — results must still be bitwise equal (the artifact is a pure
    // function of the key, so warm/cold is unobservable in the math).
    let c = coordinator();
    for solver in ["hdpwbatchsgd", "pwgradient"] {
        let mut req = base_req(solver, 2048, 300);
        req.trials = 3;
        req.reuse_precond = true;
        let r1 = c.run_job(&req).unwrap();
        let hits_after_first = c.precond_cache().hits();
        let r2 = c.run_job(&req).unwrap();
        assert_bitwise_equal(&r1, &r2, solver);
        assert!(
            c.precond_cache().hits() > hits_after_first,
            "{solver}: second run should hit the cache"
        );
    }
}

#[test]
fn every_solver_replays_bitwise_on_the_default_path() {
    // acceptance: default-path traces are deterministic for every solver in
    // the registry (the driver refactor preserved each solver's rng order).
    let c = coordinator();
    for solver in hdpw::solvers::all_names() {
        let req = base_req(solver, 1024, 150);
        let r1 = c.run_job(&req).unwrap();
        let r2 = c.run_job(&req).unwrap();
        assert_bitwise_equal(&r1, &r2, solver);
        assert_eq!(
            r1.best.precond_cache,
            CacheOutcome::Off,
            "{solver}: default path must not consult the cache"
        );
    }
    assert_eq!(c.precond_cache().hits() + c.precond_cache().misses(), 0);
}

#[test]
fn second_identical_job_hits_cache_with_near_zero_setup() {
    // acceptance: with reuse_precond=true, a second identical job on the
    // same dataset reports a recorded cache hit and setup_secs collapsed to
    // the lookup cost.
    let c = coordinator();
    let mut req = base_req("pwgradient", 16_384, 50);
    req.reuse_precond = true;
    let r1 = c.run_job(&req).unwrap();
    assert_eq!(r1.best.precond_cache, CacheOutcome::Miss);
    assert!(r1.best.setup_secs > 0.0, "miss pays the sketch + QR");
    let r2 = c.run_job(&req).unwrap();
    assert_eq!(r2.best.precond_cache, CacheOutcome::Hit);
    assert!(c.precond_cache().hits() >= 1);
    // hit setup = hashmap lookup; miss setup = streamed sketch of a
    // 16384 x 20 matrix + QR + pinv. Orders of magnitude apart; assert a
    // conservative factor to stay robust on noisy CI boxes.
    assert!(
        r2.best.setup_secs < r1.best.setup_secs,
        "hit setup {} must be below miss setup {}",
        r2.best.setup_secs,
        r1.best.setup_secs
    );
    // and the solves agree (key-derived artifact => identical math)
    assert_eq!(r1.best.x, r2.best.x);
}

#[test]
fn cache_and_default_paths_both_solve_correctly() {
    // the reuse path changes where the sketch comes from, never the math:
    // both paths must reach the optimum on a well-conditioned problem.
    let c = coordinator();
    for reuse in [false, true] {
        let mut req = base_req("pwgradient", 4096, 200);
        req.reuse_precond = reuse;
        req.target_rel_err = 1e-8;
        let res = c.run_job(&req).unwrap();
        assert!(
            res.best_rel_err < 1e-8,
            "reuse={reuse}: rel {}",
            res.best_rel_err
        );
    }
}

#[test]
fn constrained_solvers_reuse_the_metric_projector() {
    // R-metric projection reuse: constrained jobs under reuse share the
    // artifact's lazily built projector; results stay feasible and correct.
    let c = coordinator();
    let mut req = base_req("hdpwbatchsgd", 2048, 500);
    req.constraint = "l2".into();
    req.reuse_precond = true;
    req.trials = 2;
    let res = c.run_job(&req).unwrap();
    assert!(res.best_rel_err < 0.5, "rel {}", res.best_rel_err);
    // 1 miss (trial 0) + 1 hit (trial 1): one artifact, one eigendecomposition
    assert_eq!(c.precond_cache().entries(), 1);
    assert_eq!(c.precond_cache().hits(), 1);
}

#[test]
fn warm_start_across_trials_is_deterministic_and_feasible() {
    let c = coordinator();
    let mut req = base_req("hdpwbatchsgd", 1024, 200);
    req.constraint = "l1".into();
    req.warm_start = true;
    req.trials = 3;
    let r1 = c.run_job(&req).unwrap();
    let r2 = c.run_job(&req).unwrap();
    assert_bitwise_equal(&r1, &r2, "warm-start hdpw");
    assert!(r1.best_rel_err < 1.0);
}
