//! Coordinator concurrency stress (ISSUE 3 satellite): 16 threads
//! submitting identical and distinct jobs through the scheduler, asserting
//! single-flight precond-cache accounting (exactly one recorded miss per
//! key), liveness under cache eviction pressure, and bitwise-equal results
//! for identical requests.
//!
//! Extended for the serve tier (ISSUE 7): request coalescing stays
//! bit-identical to serial execution, high-priority jobs overtake a batch
//! backlog, and deadline sheds are structured errors disjoint from failures.

use hdpw::backend::Backend;
use hdpw::coordinator::job::is_shed_error;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest, JobResult};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};

const THREADS: usize = 16;

fn coordinator(precond_cache_bytes: usize) -> Arc<Coordinator> {
    Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers: THREADS,
            max_queue: 64,
            cache_dir: None,
            precond_cache_bytes,
            ..CoordinatorConfig::default()
        },
    ))
}

fn req(seed: u64) -> JobRequest {
    let mut r = JobRequest::default();
    r.dataset = "syn2".into();
    r.n = 2048;
    r.solver = "pwgradient".into();
    r.max_iters = 40;
    r.batch_size = 16;
    r.time_budget = 1e9;
    r.trials = 1;
    r.seed = seed;
    r.reuse_precond = true; // the cache is the subject under test
    r.warm_start = false;
    r.format = "dense".into(); // pin against the HDPW_FORMAT CI variant
    r
}

fn assert_bitwise_equal(a: &JobResult, b: &JobResult, tag: &str) {
    assert_eq!(a.best.x, b.best.x, "{tag}: best x differs");
    assert_eq!(a.best_f.to_bits(), b.best_f.to_bits(), "{tag}: best f differs");
    assert_eq!(a.best.iters, b.best.iters, "{tag}: iters differ");
}

/// 16 threads, one identical request each, released simultaneously: the
/// single-flight claim must elect exactly one computer (one recorded miss),
/// everyone else waits and hits, and all results are bitwise equal.
#[test]
fn identical_concurrent_jobs_record_exactly_one_miss() {
    let coord = coordinator(1 << 30);
    let barrier = Arc::new(Barrier::new(THREADS));
    let mut handles = Vec::new();
    for _ in 0..THREADS {
        let coord = Arc::clone(&coord);
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            coord.run_job(&req(11)).unwrap()
        }));
    }
    let results: Vec<JobResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(
        coord.precond_cache().misses(),
        1,
        "single-flight: exactly one miss for one key"
    );
    assert_eq!(
        coord.precond_cache().hits(),
        THREADS - 1,
        "every other caller hits the published artifact"
    );
    assert_eq!(coord.precond_cache().entries(), 1);
    for r in &results[1..] {
        assert_bitwise_equal(&results[0], r, "identical request");
    }
}

/// Distinct keys from 16 threads, big budget: one miss per key, never more.
#[test]
fn distinct_concurrent_jobs_miss_once_per_key() {
    let coord = coordinator(1 << 30);
    let barrier = Arc::new(Barrier::new(THREADS));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let coord = Arc::clone(&coord);
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            coord.run_job(&req(100 + t as u64)).unwrap()
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        coord.precond_cache().misses(),
        THREADS,
        "each distinct key computes exactly once"
    );
    assert_eq!(coord.precond_cache().hits(), 0);
    assert_eq!(coord.precond_cache().entries(), THREADS);
}

/// Eviction pressure: a budget that holds only a couple of artifacts while
/// 16 threads churn distinct keys AND re-request a shared key. Must
/// complete (no deadlock between the single-flight condvar and eviction),
/// evict continuously, and keep identical requests bitwise equal even when
/// their artifact was evicted and recomputed (keyed artifacts are pure
/// functions of the key).
#[test]
fn eviction_pressure_keeps_liveness_and_determinism() {
    // pwgradient artifacts on syn2 (d=20) are ~tens of KiB: a 64 KiB budget
    // forces constant eviction without starving a single insert
    let coord = coordinator(64 << 10);
    let barrier = Arc::new(Barrier::new(THREADS));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let coord = Arc::clone(&coord);
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            // churn: a private key, the shared key, another private key,
            // the shared key again — interleaved across all threads
            let own1 = coord.run_job(&req(500 + t as u64)).unwrap();
            let shared1 = coord.run_job(&req(7)).unwrap();
            let own2 = coord.run_job(&req(800 + t as u64)).unwrap();
            let shared2 = coord.run_job(&req(7)).unwrap();
            (own1, shared1, own2, shared2)
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(
        coord.precond_cache().evictions() > 0,
        "budget of a couple artifacts under 48 jobs must evict"
    );
    // identical requests agree bitwise across threads and across
    // evict/recompute cycles
    let reference = &results[0].1;
    for (own1, shared1, own2, shared2) in &results {
        assert_bitwise_equal(reference, shared1, "shared key (first pass)");
        assert_bitwise_equal(reference, shared2, "shared key (after churn)");
        // private keys solved correctly too
        for own in [own1, own2] {
            assert!(own.best_rel_err < 1e-6, "rel {}", own.best_rel_err);
        }
    }
}

/// The async submit path under the same contention: the worker pool with 16
/// workers, mixed identical/distinct jobs, drained cleanly with every
/// completion accounted.
#[test]
fn submit_path_under_contention_completes_all_jobs() {
    let coord = coordinator(1 << 30);
    let total = 32usize;
    let done = Arc::new(std::sync::Mutex::new(Vec::<JobResult>::new()));
    for i in 0..total {
        let done = Arc::clone(&done);
        // half identical (seed 3, even ids), half distinct
        let seed = if i % 2 == 0 { 3 } else { 1000 + i as u64 };
        let mut r = req(seed);
        r.id = i as u64;
        coord.submit(r, move |res| {
            done.lock().unwrap().push(res.unwrap());
        });
    }
    coord.drain();
    let results = done.lock().unwrap();
    assert_eq!(results.len(), total);
    // the identical half (even ids) agree bitwise
    let identical: Vec<&JobResult> = results.iter().filter(|r| r.id % 2 == 0).collect();
    assert_eq!(identical.len(), total / 2);
    for r in &identical[1..] {
        assert_bitwise_equal(identical[0], r, "submit-path identical request");
    }
    // exactly 1 miss for seed 3 plus one per distinct seed
    assert_eq!(coord.precond_cache().misses(), 1 + total / 2);
    assert_eq!(
        coord
            .metrics
            .jobs_completed
            .load(std::sync::atomic::Ordering::Relaxed),
        total
    );
}

/// ≥8 concurrent same-key `reuse_precond` jobs share one coalescing episode
/// (`coalesced_batch > 1`) while every member's trace stays bit-identical to
/// the same request run alone on a fresh coordinator. Overlap is a property
/// of the OS scheduler, so a round that happened to serialize all 8 jobs
/// retries with a fresh key instead of flaking.
#[test]
fn coalesced_group_matches_serial_execution_bitwise() {
    const GROUP: usize = 8;
    let coord = coordinator(1 << 30);
    for round in 0..5u64 {
        let seeded = req(9000 + round); // fresh key => fresh episode
        let serial = coordinator(1 << 30).run_job(&seeded).unwrap();
        assert_eq!(serial.coalesced_batch, 1, "a lone job never coalesces");
        let barrier = Arc::new(Barrier::new(GROUP));
        let mut handles = Vec::new();
        for _ in 0..GROUP {
            let coord = Arc::clone(&coord);
            let barrier = Arc::clone(&barrier);
            let r = seeded.clone();
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                coord.run_job(&r).unwrap()
            }));
        }
        let results: Vec<JobResult> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results {
            assert_bitwise_equal(&serial, r, "coalesced member vs serial run");
        }
        if results.iter().any(|r| r.coalesced_batch > 1) {
            return; // a real shared episode, with bit-identical traces
        }
    }
    panic!("coalesced_batch > 1 never observed across 5 rounds of 8 concurrent same-key jobs");
}

/// One worker and a batch backlog, then a high-priority job: the weighted
/// lane pattern must pull the high job ahead of the waiting batch work
/// instead of draining the backlog FIFO (the classic priority inversion).
#[test]
fn high_priority_job_overtakes_batch_backlog() {
    let coord = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers: 1,
            max_queue: 32,
            cache_dir: None,
            precond_cache_bytes: 1 << 30,
            ..CoordinatorConfig::default()
        },
    ));
    let order = Arc::new(std::sync::Mutex::new(Vec::<u64>::new()));
    let submit = |id: u64, priority: &str| {
        let mut r = req(40 + id); // distinct keys: no coalescing in play
        r.id = id;
        r.priority = priority.into();
        let order = Arc::clone(&order);
        coord.submit(r, move |res| {
            res.unwrap();
            order.lock().unwrap().push(id);
        });
    };
    // ids 1..=6 pile onto the batch lane while the lone worker is busy
    for id in 1..=6 {
        submit(id, "batch");
    }
    submit(7, "high");
    coord.drain();
    let order = order.lock().unwrap();
    assert_eq!(order.len(), 7);
    let high_pos = order.iter().position(|&id| id == 7).unwrap();
    // the worker may already hold one batch job and the 4:2:1 pattern may
    // grant one more slot, but the bulk of the backlog must finish after
    let batch_after = order[high_pos + 1..].len();
    assert!(
        batch_after >= 4,
        "high job finished at position {high_pos} of {:?}; \
         a priority-aware pool must overtake the batch backlog",
        &order[..]
    );
}

/// Deadline shedding under a loaded queue returns the structured shed error
/// (classifiable via `is_shed_error`, not a timeout), keeps sheds disjoint
/// from `jobs_failed`, and leaves the undoomed jobs' completions intact.
#[test]
fn deadline_sheds_under_load_are_structured_and_disjoint_from_failures() {
    let coord = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers: 1,
            max_queue: 32,
            cache_dir: None,
            precond_cache_bytes: 1 << 30,
            ..CoordinatorConfig::default()
        },
    ));
    // seed the latency histogram so submit-time estimation is armed
    coord.run_job(&req(60)).unwrap();
    let ok = Arc::new(AtomicUsize::new(0));
    let sheds = Arc::new(std::sync::Mutex::new(Vec::new()));
    for i in 0..12u64 {
        let mut r = req(61 + i);
        r.id = i;
        if i % 3 == 2 {
            // the lone worker is deep in earlier jobs: a microsecond-scale
            // deadline cannot be met at either shed checkpoint
            r.priority = "batch".into();
            r.deadline_ms = 1e-4;
        }
        let ok = Arc::clone(&ok);
        let sheds = Arc::clone(&sheds);
        coord.submit(r, move |res| match res {
            Ok(_) => {
                ok.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => sheds.lock().unwrap().push(e),
        });
    }
    coord.drain();
    let sheds = sheds.lock().unwrap();
    assert_eq!(ok.load(Ordering::Relaxed), 8, "undoomed jobs all complete");
    assert_eq!(sheds.len(), 4, "every doomed job sheds");
    for e in sheds.iter() {
        assert!(is_shed_error(e), "classifiable shed, got: {e:#}");
        assert!(format!("{e:#}").contains("deadline"));
    }
    let m = &coord.metrics;
    assert_eq!(m.jobs_shed.load(Ordering::Relaxed), 4);
    assert_eq!(
        m.jobs_failed.load(Ordering::Relaxed),
        0,
        "a shed is the scheduler declining work, not a failure"
    );
}
