//! Golden convergence fixtures — a drift detector for every iterative
//! solver (ISSUE 3 satellite).
//!
//! For each iterative solver on syn1 (kappa = 1e8) and syn2 (kappa = 1e3),
//! a seeded, iteration-bounded run produces a relative-error-vs-iteration
//! trace. The trace is compared point-by-point against the committed JSON
//! fixture under `tests/golden/` with a tight relative tolerance — any
//! change to solver numerics, rng consumption order, preconditioning, or
//! the driver loop shows up as a failing diff instead of silently shifting
//! convergence behavior (which `solver_convergence.rs`'s loose qualitative
//! assertions would absorb).
//!
//! **Bootstrap/regeneration**: a missing fixture is written from the
//! current run and the test passes (self-sealing, insta-style) — commit
//! the generated files. After an *intentional* numerics change:
//!
//! ```text
//! rm rust/tests/golden/*.json && cargo test --test solver_golden
//! ```
//!
//! then commit the regenerated fixtures. Every run additionally replays
//! each configuration twice and asserts bitwise equality, so determinism
//! is enforced even on a bootstrap run.
//!
//! The runs pin `format: dense`, `reuse_precond: false`,
//! `warm_start: false` and `executor: native` explicitly — the fixtures
//! must not depend on the HDPW_FORMAT / HDPW_REUSE_PRECOND /
//! HDPW_WARM_START / HDPW_EXECUTOR CI variants. (The simd executor's
//! FMA/re-association drift is covered by `simd_parity.rs` instead.)

use hdpw::backend::Backend;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
use hdpw::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

const DATASETS: [&str; 2] = ["syn1", "syn2"];

/// (solver, max_iters, chunk-ish): full-gradient solvers get few expensive
/// iterations, stochastic solvers get enough steps for a real trace.
const SOLVERS: [(&str, usize); 9] = [
    ("hdpwbatchsgd", 400),
    ("hdpwaccbatchsgd", 400),
    ("pwgradient", 40),
    ("ihs", 15),
    ("pwsgd", 400),
    ("sgd", 400),
    ("adagrad", 400),
    ("svrg", 400),
    ("pwsvrg", 400),
];

const SEED: u64 = 42;
const N: usize = 2048;

/// Per-point relative tolerance. The fixture is replayed on the platform
/// that generated it (CI), where runs are bitwise-deterministic; the
/// tolerance only absorbs libm differences if the fixture ever crosses
/// platforms.
const TOL: f64 = 1e-9;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn request(solver: &str, dataset: &str, max_iters: usize) -> JobRequest {
    let mut req = JobRequest::default();
    req.dataset = dataset.into();
    req.n = N;
    req.solver = solver.into();
    req.max_iters = max_iters;
    req.batch_size = 16;
    req.seed = SEED;
    req.trials = 1;
    req.time_budget = 1e9; // determinism: stop on iteration count only
    // pin the protocol knobs the CI env variants flip
    req.reuse_precond = false;
    req.warm_start = false;
    req.format = "dense".into();
    req.executor = "native".into();
    req
}

/// Run one configuration; returns (f_star, trace of (iters, rel_err)).
fn run_trace(
    coord: &Coordinator,
    solver: &str,
    dataset: &str,
    max_iters: usize,
) -> (f64, Vec<(usize, f64)>) {
    let res = coord.run_job(&request(solver, dataset, max_iters)).unwrap();
    let trace = res
        .best
        .trace
        .iter()
        .map(|p| {
            let rel = ((p.f - res.f_star) / res.f_star.max(1e-300)).max(0.0);
            (p.iters, rel)
        })
        .collect();
    (res.f_star, trace)
}

fn fixture_json(solver: &str, dataset: &str, f_star: f64, trace: &[(usize, f64)]) -> Json {
    let points: Vec<Json> = trace
        .iter()
        .map(|&(it, rel)| Json::Arr(vec![Json::num(it as f64), Json::num(rel)]))
        .collect();
    Json::obj(vec![
        ("solver", Json::str(solver)),
        ("dataset", Json::str(dataset)),
        ("n", Json::num(N as f64)),
        ("seed", Json::num(SEED as f64)),
        ("f_star", Json::num(f_star)),
        ("trace", Json::Arr(points)),
    ])
}

#[test]
fn golden_traces_replay() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).expect("create golden dir");
    let coord = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig::default(),
    ));
    let mut bootstrapped = Vec::new();
    for dataset in DATASETS {
        for (solver, max_iters) in SOLVERS {
            let (f_star, trace) = run_trace(&coord, solver, dataset, max_iters);
            assert!(trace.len() >= 2, "{solver}/{dataset}: degenerate trace");

            // determinism gate: an immediate replay must be bit-identical —
            // this holds even on a bootstrap run, so a flaky solver can
            // never seal a flaky fixture
            let (f_star2, trace2) = run_trace(&coord, solver, dataset, max_iters);
            assert_eq!(f_star.to_bits(), f_star2.to_bits(), "{solver}/{dataset}: f* replay");
            assert_eq!(trace.len(), trace2.len(), "{solver}/{dataset}");
            for (a, b) in trace.iter().zip(&trace2) {
                assert_eq!(a.0, b.0, "{solver}/{dataset}: iters replay");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "{solver}/{dataset}: rel-err replay");
            }

            let path = dir.join(format!("{solver}_{dataset}.json"));
            if !path.exists() {
                // bootstrap: seal the fixture from this (replay-verified) run
                let json = fixture_json(solver, dataset, f_star, &trace);
                std::fs::write(&path, format!("{json}\n")).expect("write fixture");
                bootstrapped.push(format!("{solver}_{dataset}"));
                continue;
            }
            let text = std::fs::read_to_string(&path).expect("read fixture");
            let golden = Json::parse(text.trim()).expect("parse fixture");
            let gpoints = golden
                .get("trace")
                .and_then(Json::as_arr)
                .expect("fixture trace");
            assert_eq!(
                gpoints.len(),
                trace.len(),
                "{solver}/{dataset}: trace length drifted (regenerate if intentional: \
                 rm rust/tests/golden/*.json && cargo test --test solver_golden)"
            );
            let gf = golden.get("f_star").and_then(Json::as_f64).unwrap();
            assert!(
                (gf - f_star).abs() <= TOL * (1.0 + gf.abs()),
                "{solver}/{dataset}: f* drifted: {f_star} vs golden {gf}"
            );
            for (k, (gp, &(it, rel))) in gpoints.iter().zip(&trace).enumerate() {
                let garr = gp.as_arr().expect("point");
                let git = garr[0].as_f64().unwrap() as usize;
                let grel = garr[1].as_f64().unwrap();
                assert_eq!(git, it, "{solver}/{dataset}: trace[{k}] iteration drifted");
                assert!(
                    (grel - rel).abs() <= TOL * (1.0 + grel.abs()),
                    "{solver}/{dataset}: trace[{k}] rel-err drifted: {rel} vs golden {grel} \
                     (regenerate if intentional: rm rust/tests/golden/*.json && \
                     cargo test --test solver_golden)"
                );
            }
        }
    }
    if !bootstrapped.is_empty() {
        eprintln!(
            "solver_golden: bootstrapped {} fixture(s) under tests/golden/ — commit them: {:?}",
            bootstrapped.len(),
            bootstrapped
        );
    }
}
