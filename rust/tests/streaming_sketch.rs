//! Property tests for the streaming block-sharded sketch/precondition
//! pipeline: the block-streamed parallel path must reproduce the dense
//! single-pass path to 1e-12 — for the sketched product `SA`, the QR factor
//! `R` built from it, and the HD-transform output — across every
//! `SketchKind`, a sweep of block sizes, and odd row counts including the
//! FWHT power-of-two padding edge.

use hdpw::backend::Backend;
use hdpw::linalg::{qr, Mat};
use hdpw::precond::{hd_transform_with, precondition_with};
use hdpw::sketch::{apply_streamed, fwht, SketchKind};
use hdpw::util::rng::Rng;

const KINDS: [SketchKind; 4] = [
    SketchKind::CountSketch,
    SketchKind::Gaussian,
    SketchKind::SparseEmbed,
    SketchKind::Srht,
];

#[test]
fn streamed_sa_and_r_match_dense_across_kinds_blocks_and_shapes() {
    let d = 7;
    let s = 48;
    // odd counts, a power of two, and 500 (pads to 512 inside SRHT)
    for n in [64usize, 333, 500, 501] {
        let mut rng = Rng::new(1000 + n as u64);
        let a = Mat::gaussian(n, d, &mut rng);
        for kind in KINDS {
            // identical rng stream for the dense reference and streamed run
            let mut r1 = Rng::new(7 * n as u64 + 1);
            let sk_dense = kind.build(s, n, &mut r1);
            let dense = sk_dense.apply(&a);
            let dense_r = qr::qr_r(&dense);
            for block in [1usize, 7, 64, 100, 4096] {
                let mut r2 = Rng::new(7 * n as u64 + 1);
                let sk = kind.build(s, n, &mut r2);
                for threads in [1usize, 4] {
                    let (sa, shards) =
                        apply_streamed(sk.as_ref(), &a, Some(block), threads);
                    assert_eq!((sa.rows, sa.cols), (s, d));
                    let diff = sa.max_abs_diff(&dense);
                    assert!(
                        diff < 1e-12,
                        "{} n={n} block={block} threads={threads}: SA diff {diff}",
                        kind.name()
                    );
                    let r = qr::qr_r(&sa);
                    let rdiff = r.max_abs_diff(&dense_r);
                    assert!(
                        rdiff < 1e-12,
                        "{} n={n} block={block} threads={threads}: R diff {rdiff}",
                        kind.name()
                    );
                    if kind == SketchKind::Srht {
                        // documented dense fallback: one pass, never sharded
                        assert_eq!(shards, 1, "SRHT must not claim streaming");
                    } else if block < n {
                        assert!(
                            shards > 1,
                            "{} n={n} block={block}: expected shards",
                            kind.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn hd_pipeline_matches_reference_at_padding_edges() {
    // 500 -> 512 pad (the FWHT power-of-two edge), 512 -> no pad, 513 -> 1024
    for n in [500usize, 512, 513] {
        let d = 5;
        let mut rng = Rng::new(n as u64);
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);

        // reference: the seed's materialize-everything chain
        let mut r1 = Rng::new(77);
        let bmat = Mat::from_vec(n, 1, b.clone());
        let packed = a.hstack(&bmat);
        let n_pad = n.next_power_of_two();
        let mut padded = if n_pad == n { packed } else { packed.pad_rows(n_pad) };
        let signs = r1.signs(n_pad);
        fwht::randomized_hadamard(&mut padded, &signs);
        let (want_hda, want_hdb) = padded.split_last_col();

        // streaming pipeline: single packed allocation, in-place transform
        let mut r2 = Rng::new(77);
        let hd = hd_transform_with(&Backend::native(), &a, &b, &mut r2);
        assert_eq!(hd.n_pad, n_pad, "n={n}");
        assert_eq!(hd.hda.rows, n_pad);
        let adiff = hd.hda.max_abs_diff(&want_hda);
        assert!(adiff < 1e-14, "n={n}: HDA diff {adiff}");
        for (x, y) in hd.hdb.iter().zip(&want_hdb) {
            assert!((x - y).abs() < 1e-14, "n={n}: HDb mismatch");
        }
    }
}

/// Acceptance criterion: `precondition` on a 2^17 x 50 synthetic dataset
/// runs the block-streamed parallel path (DispatchStats shows >1 native
/// block call) and returns `R` equal to the dense-path `R` within 1e-12.
/// The dense [A | b] is never cloned before sketching: `precondition_with`
/// consumes row shards of `A` in place, and the HD step builds its single
/// padded buffer directly (`Mat::hstack_col_padded`).
#[test]
fn precondition_2pow17_by_50_streams_blocks_and_matches_dense_r() {
    let n = 1 << 17;
    let d = 50;
    let s = 2048; // rotation-scale sketch: keeps the dense reference cheap
    let mut rng = Rng::new(20180201);
    let a = Mat::gaussian(n, d, &mut rng);

    // dense reference from an identical sketch sample
    let mut r1 = Rng::new(9);
    let sk = SketchKind::CountSketch.build(s, n, &mut r1);
    let dense_r = qr::qr_r(&sk.apply(&a));

    let backend = Backend::native();
    let mut r2 = Rng::new(9);
    let pre = precondition_with(&backend, &a, SketchKind::CountSketch, s, &mut r2, None);

    assert!(
        backend.native_block_calls() > 1,
        "expected the block-streamed parallel path, got {} block calls",
        backend.native_block_calls()
    );
    let rdiff = pre.r.max_abs_diff(&dense_r);
    assert!(rdiff < 1e-12, "streamed R != dense R: diff {rdiff}");
    assert_eq!(pre.r.rows, d);
    assert_eq!(pre.sketch_rows, s);
}
