//! Batched hot-path equivalence gates (ISSUE 9).
//!
//! Three bit-identity contracts of the batched execution layer:
//!
//! 1. **Blockwise implicit-HD gather** — `gather_rows_csr_blocked` reorders
//!    only *memory traffic* (source rows outer, sampled rows inner); per
//!    output cell the same coefficients accumulate in the same ascending-j
//!    order with plain mul+add, so every block size must reproduce the
//!    per-row reference bit for bit, across odd-n padding and power-of-two
//!    edges.
//! 2. **`hd_scatter_row` kernel** — the dispatched simd entry, the explicit
//!    `F64x4Scalar` instantiation, and a plain scalar loop must agree
//!    bitwise (the kernel vectorizes the response panel with lanewise
//!    mul+add, never FMA, and keeps the design scatter scalar).
//! 3. **Fused batching** — `drive_fused_trials` (cross-trial objective
//!    fusion) replayed against serial `Solver::solve` of the same opts must
//!    be bitwise equal per trial; at the coordinator level, fused trials
//!    and adopted cross-request results must be bitwise equal to a solo
//!    run of the same request.

use hdpw::backend::Backend;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
use hdpw::data::Dataset;
use hdpw::linalg::{blas, CsrMat, Mat};
use hdpw::precond::{hd_implicit_ds, PrecondCache};
use hdpw::simd::{self, F64x4Scalar};
use hdpw::solvers::{self, drive_fused_trials, SessionCtx, SolveReport, SolverOpts};
use hdpw::util::rng::Rng;
use std::sync::Arc;

fn sparse_ds(n: usize, d: usize, density: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let dense = Mat::from_fn(n, d, |_, _| {
        if rng.uniform() < density {
            rng.gaussian()
        } else {
            0.0
        }
    });
    let xt = rng.gaussians(d);
    let mut b = blas::gemv(&dense, &xt);
    for v in &mut b {
        *v += 0.05 * rng.gaussian();
    }
    Dataset::from_csr("sp", CsrMat::from_dense(&dense), b, None)
}

fn dense_ds(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let a = Mat::gaussian(n, d, &mut rng);
    let xt = rng.gaussians(d);
    let mut b = blas::gemv(&a, &xt);
    for v in &mut b {
        *v += 0.05 * rng.gaussian();
    }
    Dataset::dense("dn", a, b, None)
}

fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
    }
}

// -------------------------------------------------------------------------
// 1. blockwise gather vs per-row reference
// -------------------------------------------------------------------------

#[test]
fn blockwise_gather_matches_per_row_reference_across_shapes() {
    // odd n (padding adds virtual rows), exact power of two, and a tall
    // shape; batches from a single row to larger than the universe
    for (n, d, seed) in [(50usize, 3usize, 21u64), (64, 5, 22), (300, 9, 23), (1000, 7, 24)] {
        let ds = sparse_ds(n, d, 0.25, seed);
        let csr = ds.csr().expect("sparse dataset");
        let mut rng = Rng::new(seed ^ 0xbeef);
        let mut art_rng = Rng::new(seed);
        let hd = hd_implicit_ds(&ds, &mut art_rng);
        assert_eq!(hd.n_pad, n.next_power_of_two());
        for r in [1usize, 2, 7, 33, 128, 257] {
            // sample over the FULL padded universe, virtual rows included
            let idx: Vec<usize> = (0..r)
                .map(|_| (rng.next_u64() as usize) % hd.n_pad)
                .collect();
            let (wm, wb) = hd.gather_rows_csr_ref(csr, &ds.b, &idx);
            for block in [0usize, 1, 3, 64, 128, 1 << 20] {
                let (gm, gb) = hd.gather_rows_csr_blocked(csr, &ds.b, &idx, block);
                assert_eq!(
                    gm.max_abs_diff(&wm),
                    0.0,
                    "design panel n={n} r={r} block={block}"
                );
                assert_bits_eq(&gb, &wb, &format!("response n={n} r={r} block={block}"));
            }
        }
        // edge batches: every index the same row, and the last padded row
        let (wm, wb) = hd.gather_rows_csr_ref(csr, &ds.b, &[hd.n_pad - 1; 5]);
        let (gm, gb) = hd.gather_rows_csr_blocked(csr, &ds.b, &[hd.n_pad - 1; 5], 2);
        assert_eq!(gm.max_abs_diff(&wm), 0.0, "repeated-tail n={n}");
        assert_bits_eq(&gb, &wb, "repeated-tail responses");
    }
}

// -------------------------------------------------------------------------
// 2. the scatter kernel is bitwise across instantiations
// -------------------------------------------------------------------------

#[test]
fn hd_scatter_row_kernel_is_bitwise_across_instantiations() {
    let mut rng = Rng::new(77);
    let ld = 41usize;
    for nnz in [0usize, 1, 3, 8, 31] {
        for r in [1usize, 4, 5, 16, 33] {
            // sorted distinct columns inside the row bound
            let mut cols: Vec<u32> = (0..ld as u32).collect();
            for i in (1..cols.len()).rev() {
                cols.swap(i, (rng.next_u64() as usize) % (i + 1));
            }
            cols.truncate(nnz);
            cols.sort_unstable();
            let vals = rng.gaussians(nnz);
            let coeffs = rng.gaussians(r);
            let bj = rng.gaussian();
            // non-zero initial accumulators: the kernel must *add*
            let out0 = rng.gaussians(r * ld);
            let outb0 = rng.gaussians(r);

            let (mut got, mut gotb) = (out0.clone(), outb0.clone());
            simd::hd_scatter_row(&cols, &vals, bj, &coeffs, &mut got, ld, &mut gotb);

            let (mut exp, mut expb) = (out0.clone(), outb0.clone());
            // SAFETY: F64x4Scalar is plain Rust (no instruction-set
            // requirement); slice contracts hold by construction
            unsafe {
                simd::kernels::hd_scatter_row::<F64x4Scalar>(
                    &cols, &vals, bj, &coeffs, &mut exp, ld, &mut expb,
                );
            }
            assert_bits_eq(&got, &exp, "dispatched vs F64x4Scalar design");
            assert_bits_eq(&gotb, &expb, "dispatched vs F64x4Scalar response");

            // plain scalar reference: same mul+add per element, ascending
            // column order — the documented kernel contract
            let (mut refo, mut refb) = (out0.clone(), outb0.clone());
            for t in 0..r {
                refb[t] += coeffs[t] * bj;
                for (c, v) in cols.iter().zip(&vals) {
                    refo[t * ld + *c as usize] += coeffs[t] * v;
                }
            }
            assert_bits_eq(&got, &refo, "dispatched vs scalar loop design");
            assert_bits_eq(&gotb, &refb, "dispatched vs scalar loop response");
        }
    }
}

// -------------------------------------------------------------------------
// 3. fused batching replayed against the serial path
// -------------------------------------------------------------------------

fn fused_opts(seed: u64, cache: &Arc<PrecondCache>) -> SolverOpts {
    let mut opts = SolverOpts::default();
    opts.batch_size = 16;
    opts.max_iters = 300;
    opts.chunk = 60;
    opts.time_budget = 1e9; // wall-clock must never gate the comparison
    opts.seed = seed;
    opts.session = SessionCtx {
        reuse_precond: true,
        warm_start: false,
        cache: Some(Arc::clone(cache)),
        dataset_id: Some("replay".into()),
        artifact_seed: 7,
        x0: None,
        mem: None,
    };
    opts
}

#[test]
fn fused_trials_are_bitwise_equal_to_serial_drive() {
    let backend = Backend::native();
    for (name, sparse) in [
        ("hdpwbatchsgd", false),
        ("hdpwbatchsgd", true),
        ("pwgradient", false),
        ("hdpwaccbatchsgd", true),
    ] {
        let ds = if sparse {
            sparse_ds(768, 5, 0.2, 31)
        } else {
            dense_ds(768, 5, 31)
        };
        let solver = solvers::by_name(name).expect("known solver");
        // each path gets its OWN fresh cache: artifacts are pure functions
        // of (key, seed), so per-path caches reproduce the same miss/hit
        // sequence and the same bits
        let fused_cache = Arc::new(PrecondCache::new(64 << 20));
        let serial_cache = Arc::new(PrecondCache::new(64 << 20));
        let opts_fused: Vec<SolverOpts> =
            [11u64, 22, 33].iter().map(|&s| fused_opts(s, &fused_cache)).collect();
        let fused = drive_fused_trials(solver.as_ref(), &backend, &ds, &opts_fused)
            .unwrap_or_else(|e| panic!("{name} fused: {e:#}"));
        let serial: Vec<SolveReport> = [11u64, 22, 33]
            .iter()
            .map(|&s| {
                solver
                    .solve(&backend, &ds, &fused_opts(s, &serial_cache))
                    .unwrap_or_else(|e| panic!("{name} serial: {e:#}"))
            })
            .collect();
        assert_eq!(fused.len(), serial.len());
        for (k, (f, s)) in fused.iter().zip(&serial).enumerate() {
            assert_eq!(f.iters, s.iters, "{name} sparse={sparse} trial {k}: iters");
            assert_eq!(
                f.f_final.to_bits(),
                s.f_final.to_bits(),
                "{name} sparse={sparse} trial {k}: f {} vs {}",
                f.f_final,
                s.f_final
            );
            assert_bits_eq(&f.x, &s.x, &format!("{name} sparse={sparse} trial {k}: x"));
            assert_eq!(
                f.trace.len(),
                s.trace.len(),
                "{name} sparse={sparse} trial {k}: trace"
            );
        }
    }
}

#[test]
fn coordinator_fused_trials_match_a_fresh_replay_and_report_batch() {
    let mk = || {
        Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig::default(),
        ))
    };
    let mut req = JobRequest::default();
    req.dataset = "syn2".into();
    req.n = 1024;
    req.solver = "hdpwbatchsgd".into();
    req.max_iters = 300;
    req.batch_size = 16;
    req.time_budget = 20.0;
    req.reuse_precond = true;
    req.trials = 3;
    let a = mk().run_job(&req).unwrap();
    let b = mk().run_job(&req).unwrap();
    assert_eq!(a.batched_trials, 3, "reuse trials run the fused driver");
    assert_eq!(a.trials_run, 3);
    assert_bits_eq(&a.best.x, &b.best.x, "fused run determinism");
    assert_eq!(a.best_f.to_bits(), b.best_f.to_bits());
    // the serial path (no reuse => nothing fusable) reports a batch of 1
    let mut solo = req.clone();
    solo.reuse_precond = false;
    let s = mk().run_job(&solo).unwrap();
    assert_eq!(s.batched_trials, 1);
    assert_eq!(s.batched_requests, 1);
}

#[test]
fn concurrent_identical_requests_adopt_the_leader_bitwise() {
    let coord = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            workers: 4,
            ..CoordinatorConfig::default()
        },
    ));
    let mut base = JobRequest::default();
    base.dataset = "syn2".into();
    base.n = 1024;
    base.solver = "pwgradient".into();
    base.max_iters = 300;
    base.time_budget = 20.0;
    base.reuse_precond = true;
    // scheduling is not deterministic: retry with a fresh seed until a
    // round actually overlaps (4 barrier-released threads, so one round
    // nearly always does)
    for round in 0..5u64 {
        let mut req = base.clone();
        req.seed = 100 + round;
        let solo = Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig::default(),
        ))
        .run_job(&req)
        .unwrap();
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4u64)
                .map(|i| {
                    let coord = Arc::clone(&coord);
                    let barrier = Arc::clone(&barrier);
                    let mut r = req.clone();
                    r.id = i; // identity is excluded from the fuse signature
                    s.spawn(move || {
                        barrier.wait();
                        coord.run_job(&r).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64, "adopted results echo the caller's id");
            assert_bits_eq(&r.best.x, &solo.best.x, "adopted result vs solo run");
            assert_eq!(r.best_f.to_bits(), solo.best_f.to_bits());
        }
        if results.iter().any(|r| r.batched_requests > 1) {
            use std::sync::atomic::Ordering;
            assert!(coord.metrics.fused_requests.load(Ordering::Relaxed) > 1);
            assert!(coord.metrics.fuse_batch_max.load(Ordering::Relaxed) > 1);
            return;
        }
    }
    panic!("4 barrier-released identical jobs never overlapped in 5 rounds");
}
