//! ISSUE-5 acceptance: every [`ConstraintSpec`] form survives the full
//! serve round trip — JSON request line in, solve, `JobResult` line out
//! with the active constraint's tag, parameter summary, and projection
//! count — and malformed/mis-dimensioned specs come back as precise
//! error lines, never crashes.

use hdpw::backend::Backend;
use hdpw::constraints::ConstraintSpec;
use hdpw::coordinator::server::handle_connection;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
use hdpw::util::json::Json;
use std::io::{Cursor, Write};
use std::sync::{Arc, Mutex};

#[derive(Clone)]
struct VecWriter(Arc<Mutex<Vec<u8>>>);

impl Write for VecWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn run_session(input: &str) -> Vec<Json> {
    let coord = Arc::new(Coordinator::new(
        Backend::native(),
        CoordinatorConfig {
            mem_budget: hdpw::util::mem::MemBudget::unlimited(),
            ..CoordinatorConfig::default()
        },
    ));
    let out = Arc::new(Mutex::new(Vec::new()));
    handle_connection(
        &coord,
        Cursor::new(input.to_string()),
        VecWriter(Arc::clone(&out)),
    )
    .unwrap();
    let bytes = out.lock().unwrap().clone();
    String::from_utf8(bytes)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect()
}

#[test]
fn every_constraint_spec_form_survives_the_serve_round_trip() {
    // syn2 has d = 20 columns: the dimension-typed specs must match it
    let d = 20;
    let specs: Vec<(ConstraintSpec, &str)> = vec![
        (ConstraintSpec::Unconstrained, "unc"),
        (ConstraintSpec::L1Ball { radius: 0.0 }, "l1"),
        (ConstraintSpec::L2Ball { radius: 0.0 }, "l2"),
        (ConstraintSpec::NonNeg, "nonneg"),
        (ConstraintSpec::Simplex { total: 1.0 }, "simplex"),
        (ConstraintSpec::ScalarBox { lo: -2.0, hi: 2.0 }, "box"),
        (
            ConstraintSpec::CoordBox {
                lo: vec![-2.0; d],
                hi: vec![2.0; d],
            },
            "box",
        ),
        (
            ConstraintSpec::ElasticNet {
                alpha: 0.5,
                radius: 0.0,
            },
            "enet",
        ),
        (
            ConstraintSpec::AffineEq {
                c: vec![vec![1.0; d]],
                e: vec![0.5],
            },
            "affine",
        ),
    ];
    let mut input = String::new();
    for (i, (spec, _)) in specs.iter().enumerate() {
        let mut req = JobRequest::default();
        req.id = i as u64;
        req.n = 256;
        req.solver = "pwgradient".into();
        req.max_iters = 40;
        req.time_budget = 20.0;
        req.trials = 1;
        req.constraint = spec.clone();
        input.push_str(&req.to_json().to_string());
        input.push('\n');
    }
    let out = run_session(&input);
    assert_eq!(out.len(), specs.len(), "{out:?}");
    for (i, (spec, tag)) in specs.iter().enumerate() {
        let line = out
            .iter()
            .find(|j| j.get("id").and_then(Json::as_f64) == Some(i as f64))
            .unwrap_or_else(|| panic!("no result line for {spec:?}: {out:?}"));
        assert!(
            line.get("error").is_none(),
            "{spec:?} errored: {line:?}"
        );
        assert_eq!(
            line.get("constraint").and_then(Json::as_str),
            Some(*tag),
            "{spec:?}"
        );
        // the params summary rides along (the old radius-only report
        // flattened everything but balls to nothing)
        let params = line
            .get("constraint_params")
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("no constraint_params for {spec:?}"));
        if *tag == "box" {
            assert!(params.contains("lo"), "{spec:?}: params {params:?}");
        }
        // every constrained job projects; the unconstrained one never does
        let projections = line
            .get("projections")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("no projections for {spec:?}"));
        if *tag == "unc" {
            assert_eq!(projections, 0.0);
        } else {
            assert!(projections > 0.0, "{spec:?}: projections {projections}");
        }
    }
}

#[test]
fn malformed_and_mis_dimensioned_specs_error_precisely() {
    // parse-time error: ragged box bounds — the error names the path
    let out = run_session(
        "{\"solver\":\"exact\",\"constraint\":{\"box\":{\"lo\":[1],\"hi\":[0,1]}}}\n",
    );
    let err = out[0].get("error").and_then(Json::as_str).expect("error line");
    assert!(err.contains("constraint.box"), "{err}");
    // admission-time error: a 3-dimensional box against syn2's d = 20
    let mut req = JobRequest::default();
    req.n = 256;
    req.solver = "exact".into();
    req.constraint = ConstraintSpec::CoordBox {
        lo: vec![0.0; 3],
        hi: vec![1.0; 3],
    };
    let out = run_session(&format!("{}\n", req.to_json()));
    let err = out[0].get("error").and_then(Json::as_str).expect("error line");
    assert!(err.contains("3-dimensional"), "{err}");
    // the legacy string form still parses over the wire
    let out = run_session(
        "{\"solver\":\"exact\",\"n\":256,\"max_iters\":5,\"constraint\":\"l2\"}\n",
    );
    assert!(out[0].get("error").is_none(), "{out:?}");
    assert_eq!(out[0].get("constraint").and_then(Json::as_str), Some("l2"));
}
