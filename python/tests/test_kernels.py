"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Sweeps shapes, dtypes and block sizes (hypothesis-style parameter grids —
the hypothesis package is not available offline, so the sweeps are explicit
parametrize grids with the same coverage intent).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from compile.kernels import ref  # noqa: E402
from compile.kernels.batch_grad import batch_grad  # noqa: E402
from compile.kernels.fwht import fwht  # noqa: E402


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


TOL = {jnp.float32: 2e-5, jnp.float64: 1e-12}


# ---------------------------------------------------------------------------
# batch_grad kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r", [1, 4, 16, 64, 256, 1024])
@pytest.mark.parametrize("d", [1, 8, 32, 90])
def test_batch_grad_matches_ref_shapes(r, d):
    rng = np.random.default_rng(r * 1000 + d)
    m = rand(rng, (r, d), jnp.float64)
    v = rand(rng, (r,), jnp.float64)
    x = rand(rng, (d,), jnp.float64)
    got = batch_grad(m, v, x)
    want = ref.batch_grad_ref(m, v, x, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_batch_grad_dtypes(dtype):
    rng = np.random.default_rng(7)
    m = rand(rng, (64, 16), dtype)
    v = rand(rng, (64,), dtype)
    x = rand(rng, (16,), dtype)
    got = batch_grad(m, v, x)
    want = ref.batch_grad_ref(m, v, x, 1.0)
    assert got.dtype == dtype
    np.testing.assert_allclose(got, want, rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("row_block", [1, 2, 8, 32])
def test_batch_grad_row_block_invariance(row_block):
    """Tiling must not change the result (accumulation over grid steps)."""
    rng = np.random.default_rng(11)
    m = rand(rng, (32, 8), jnp.float64)
    v = rand(rng, (32,), jnp.float64)
    x = rand(rng, (8,), jnp.float64)
    got = batch_grad(m, v, x, row_block=row_block)
    want = ref.batch_grad_ref(m, v, x, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_batch_grad_zero_x_gives_neg_mtv():
    rng = np.random.default_rng(13)
    m = rand(rng, (16, 4), jnp.float64)
    v = rand(rng, (16,), jnp.float64)
    x = jnp.zeros(4, jnp.float64)
    got = batch_grad(m, v, x)
    np.testing.assert_allclose(got, -(m.T @ v), rtol=1e-12)


def test_batch_grad_gradient_identity():
    """c = M^T(Mx - v) is 1/2 the gradient of ||Mx - v||^2: check against
    jax autodiff as an independent oracle."""
    rng = np.random.default_rng(17)
    m = rand(rng, (32, 8), jnp.float64)
    v = rand(rng, (32,), jnp.float64)
    x = rand(rng, (8,), jnp.float64)
    autodiff = jax.grad(lambda xx: jnp.sum((m @ xx - v) ** 2))(x)
    got = 2.0 * batch_grad(m, v, x)
    np.testing.assert_allclose(got, autodiff, rtol=1e-11, atol=1e-11)


# ---------------------------------------------------------------------------
# fwht kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 8, 64, 512, 4096])
@pytest.mark.parametrize("d", [1, 3, 33])
def test_fwht_matches_ref(n, d):
    rng = np.random.default_rng(n + d)
    u = rand(rng, (n, d), jnp.float64)
    got = fwht(u)
    want = ref.fwht_ref(u)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_fwht_matches_explicit_hadamard():
    import scipy.linalg as sl

    rng = np.random.default_rng(3)
    n = 64
    u = rand(rng, (n, 5), jnp.float64)
    h = sl.hadamard(n) / np.sqrt(n)
    np.testing.assert_allclose(fwht(u), h @ np.asarray(u), atol=1e-12)


def test_fwht_involution_and_isometry():
    rng = np.random.default_rng(5)
    u = rand(rng, (256, 7), jnp.float64)
    once = fwht(u)
    twice = fwht(once)
    np.testing.assert_allclose(twice, u, atol=1e-11)
    np.testing.assert_allclose(
        jnp.linalg.norm(once, axis=0), jnp.linalg.norm(u, axis=0), rtol=1e-12
    )


@pytest.mark.parametrize("col_block", [1, 2, 16, 128])
def test_fwht_col_block_invariance(col_block):
    rng = np.random.default_rng(9)
    u = rand(rng, (128, 10), jnp.float64)
    got = fwht(u, col_block=col_block)
    want = ref.fwht_ref(u)
    np.testing.assert_allclose(got, want, atol=1e-12)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fwht_dtypes(dtype):
    rng = np.random.default_rng(21)
    u = rand(rng, (64, 4), dtype)
    got = fwht(u)
    assert got.dtype == dtype
    np.testing.assert_allclose(got, ref.fwht_ref(u), rtol=TOL[dtype], atol=TOL[dtype])


def test_fwht_rejects_non_pow2():
    rng = np.random.default_rng(23)
    u = rand(rng, (48, 4), jnp.float64)
    with pytest.raises(AssertionError):
        fwht(u)


def test_hd_transform_spreads_rows():
    """Theorem 1 sanity: HD flattens a spiky (identity-block) matrix."""
    n, d = 512, 8
    u = jnp.zeros((n, d), jnp.float64).at[jnp.arange(d), jnp.arange(d)].set(1.0)
    rng = np.random.default_rng(29)
    sign = jnp.asarray(rng.choice([-1.0, 1.0], size=n))
    out = ref.hd_transform_ref(u, sign)
    row_norms = jnp.linalg.norm(out, axis=1)
    assert float(row_norms.max()) < 0.5  # was 1.0 before mixing
    # orthogonality: column norms preserved
    np.testing.assert_allclose(
        jnp.linalg.norm(out, axis=0), jnp.ones(d), atol=1e-12
    )
