"""L2 graph correctness: solver chunks vs straightforward numpy references,
projection properties, and AOT lowering smoke tests."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape))


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------


def test_project_l2_matches_closed_form():
    rng = np.random.default_rng(1)
    x = rand(rng, (16,)) * 3.0
    out = model.project_l2(x, 1.0)
    nrm = float(jnp.linalg.norm(x))
    if nrm > 1.0:
        np.testing.assert_allclose(out, x / nrm, rtol=1e-12)
    assert float(jnp.linalg.norm(out)) <= 1.0 + 1e-12


@pytest.mark.parametrize("seed", range(8))
def test_project_l1_on_boundary_and_optimal(seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (12,)) * 2.0
    radius = 1.0
    out = model.project_l1(x, radius)
    l1 = float(jnp.sum(jnp.abs(out)))
    if float(jnp.sum(jnp.abs(x))) > radius:
        assert abs(l1 - radius) < 1e-9
    else:
        np.testing.assert_allclose(out, x)
    # Euclidean optimality vs random feasible candidates
    d_out = float(jnp.sum((x - out) ** 2))
    for _ in range(200):
        cand = rng.standard_normal(12)
        c_l1 = np.abs(cand).sum()
        if c_l1 > radius:
            cand *= radius / c_l1
        assert float(np.sum((np.asarray(x) - cand) ** 2)) >= d_out - 1e-9


def test_project_l1_inside_is_identity():
    x = jnp.asarray([0.1, -0.2, 0.05])
    np.testing.assert_allclose(model.project_l1(x, 1.0), x)


# ---------------------------------------------------------------------------
# solver chunks vs numpy reference loops
# ---------------------------------------------------------------------------


def np_sgd_chunk(hda, hdb, x0, pinv, idx, eta, scale, radius, constraint):
    x = np.asarray(x0).copy()
    xsum = np.zeros_like(x)
    for tau in idx:
        m = hda[tau]
        v = hdb[tau]
        c = scale * (m.T @ (m @ x - v))
        x = x - eta * (pinv @ c)
        if constraint == "l2":
            nrm = np.linalg.norm(x)
            if nrm > radius:
                x = x * (radius / nrm)
        elif constraint == "l1":
            x = np.asarray(model.project_l1(jnp.asarray(x), radius))
        xsum += x
    return x, xsum


@pytest.mark.parametrize("constraint", ["unc", "l2", "l1"])
def test_sgd_chunk_matches_numpy(constraint):
    rng = np.random.default_rng(42)
    n, d, r, t = 256, 6, 4, 10
    hda = rng.standard_normal((n, d))
    hdb = rng.standard_normal(n)
    x0 = rng.standard_normal(d)
    pinv = np.eye(d) * 0.1
    idx = rng.integers(0, n, size=(t, r))
    eta, scale, radius = 0.05, 2.0 * n / r, 0.8
    got_x, got_sum = model.sgd_chunk(
        jnp.asarray(hda),
        jnp.asarray(hdb),
        jnp.asarray(x0),
        jnp.asarray(pinv),
        jnp.asarray(idx, dtype=jnp.int32),
        eta,
        scale,
        radius,
        constraint=constraint,
    )
    want_x, want_sum = np_sgd_chunk(hda, hdb, x0, pinv, idx, eta, scale, radius, constraint)
    np.testing.assert_allclose(got_x, want_x, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(got_sum, want_sum, rtol=1e-9, atol=1e-9)


def test_acc_chunk_unconstrained_matches_numpy():
    rng = np.random.default_rng(43)
    n, d, r, t = 128, 5, 4, 8
    hda = rng.standard_normal((n, d))
    hdb = rng.standard_normal(n)
    x = rng.standard_normal(d)
    xhat = rng.standard_normal(d)
    pinv = np.eye(d) * 0.05
    idx = rng.integers(0, n, size=(t, r))
    alphas = np.asarray([2.0 / (k + 2.0) for k in range(t)])
    qs = alphas.copy()
    etas = np.full(t, 0.03)
    mu, scale = 2.0, 2.0 * n / r
    got_x, got_xh = model.acc_chunk(
        jnp.asarray(hda),
        jnp.asarray(hdb),
        jnp.asarray(x),
        jnp.asarray(xhat),
        jnp.asarray(pinv),
        jnp.asarray(idx, dtype=jnp.int32),
        jnp.asarray(alphas),
        jnp.asarray(qs),
        jnp.asarray(etas),
        mu,
        scale,
        0.0,
        constraint="unc",
    )
    # numpy reference
    xn, xh = x.copy(), xhat.copy()
    for k in range(t):
        xt = (1 - qs[k]) * xh + qs[k] * xn
        m = hda[idx[k]]
        v = hdb[idx[k]]
        c = scale * (m.T @ (m @ xt - v))
        xnew = (etas[k] * mu * xt + xn - etas[k] * (pinv @ c)) / (1 + etas[k] * mu)
        xh = (1 - alphas[k]) * xh + alphas[k] * xnew
        xn = xnew
    np.testing.assert_allclose(got_x, xn, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(got_xh, xh, rtol=1e-9, atol=1e-9)


def test_pw_gradient_chunk_newton_like_with_exact_pinv():
    """With pinv = (A^T A)^{-1} and eta = 1/2, one step solves the LS problem."""
    rng = np.random.default_rng(44)
    n, d = 512, 6
    a = rng.standard_normal((n, d))
    xstar = rng.standard_normal(d)
    b = a @ xstar + 0.01 * rng.standard_normal(n)
    pinv = np.linalg.inv(a.T @ a)
    (xt,) = model.pw_gradient_chunk(
        jnp.asarray(a),
        jnp.asarray(b),
        jnp.zeros(d),
        jnp.asarray(pinv),
        0.5,
        0.0,
        T=1,
        constraint="unc",
    )
    lsq = np.linalg.lstsq(a, b, rcond=None)[0]
    np.testing.assert_allclose(xt, lsq, rtol=1e-9, atol=1e-9)


def test_hd_transform_packs_a_and_b():
    rng = np.random.default_rng(45)
    n, d = 128, 4
    aug = rng.standard_normal((n, d + 1))
    sign = rng.choice([-1.0, 1.0], size=n)
    got = model.hd_transform(jnp.asarray(aug), jnp.asarray(sign))
    want = ref.hd_transform_ref(jnp.asarray(aug), jnp.asarray(sign))
    np.testing.assert_allclose(got, want, atol=1e-11)
    # objective invariance: ||HDA x - HDb|| == ||Ax - b||
    a, b = aug[:, :d], aug[:, d]
    ha, hb = np.asarray(got)[:, :d], np.asarray(got)[:, d]
    x = rng.standard_normal(d)
    np.testing.assert_allclose(
        np.linalg.norm(ha @ x - hb), np.linalg.norm(a @ x - b), rtol=1e-10
    )


# ---------------------------------------------------------------------------
# AOT lowering smoke (tiny shapes; full artifact parity is tested from Rust)
# ---------------------------------------------------------------------------


def test_aot_lowering_all_ops_tiny():
    from compile import aot

    ops = aot.build_ops(n=64, d=4, rs=[2], chunk_t=3, pw_t=2)
    assert len(ops) >= 14
    for op in ops:
        text = aot.to_hlo_text(op["fn"], op["specs"])
        assert text.startswith("HloModule"), op["name"]
        assert "ENTRY" in text, op["name"]


def test_aot_lowering_preserves_parameter_count():
    """Regression test: unused inputs (e.g. radius in 'unc' variants) must
    not be pruned from the lowered module, or the manifest desyncs."""
    from compile import aot

    for op in aot.build_ops(n=64, d=4, rs=[2], chunk_t=3, pw_t=2):
        text = aot.to_hlo_text(op["fn"], op["specs"])
        # count parameters of the ENTRY computation only (nested scan /
        # reduce computations declare their own)
        entry = text[text.index("ENTRY") :]
        body = entry[: entry.index("\n}")]
        n_params = body.count("parameter(")
        assert n_params == len(op["specs"]), (
            f"{op['name']}: {n_params} params vs {len(op['specs'])} specs"
        )
