"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float tolerance under pytest sweeps
(python/tests/test_kernels.py). They are also the L2 fallback path when a
shape is not worth a kernel launch.
"""

import jax.numpy as jnp


def batch_grad_ref(m, v, x, scale):
    """Mini-batch gradient of ||Ax-b||^2 restricted to sampled rows.

    c = scale * M^T (M x - v), the Step-5 quantity of Algorithm 2
    (HDpwBatchSGD) with M = (HDA)_tau, v = (HDb)_tau, scale = 2n/r.
    """
    r = m @ x - v
    return scale * (m.T @ r)


def full_grad_ref(a, b, x):
    """Full gradient 2 A^T (A x - b) (pwGradient / IHS inner step)."""
    return 2.0 * (a.T @ (a @ x - b))


def fwht_ref(u):
    """Orthonormal fast Walsh-Hadamard transform along axis 0.

    u: (n, d) or (n,) with n a power of two. Returns H u with H the n x n
    Walsh-Hadamard matrix scaled by 1/sqrt(n) (Definition 2 of the paper).
    Reference implementation: explicit butterfly recursion in jnp.
    """
    n = u.shape[0]
    tail = u.shape[1:]
    h = 1
    while h < n:
        u = u.reshape((n // (2 * h), 2, h) + tail)
        a = u[:, 0]
        b = u[:, 1]
        u = jnp.stack([a + b, a - b], axis=1).reshape((n,) + tail)
        h *= 2
    return u / jnp.sqrt(jnp.asarray(n, dtype=u.dtype))


def hd_transform_ref(a, sign):
    """Randomized Hadamard transform: H D a with D = diag(sign).

    a: (n, d), sign: (n,) of +-1. This is Step 2 of Algorithm 2: the second
    preconditioning step that spreads out row norms (Theorem 1).
    """
    return fwht_ref(a * sign[:, None])


def residual_sq_ref(a, b, x):
    """f(x) = ||Ax - b||_2^2."""
    r = a @ x - b
    return jnp.dot(r, r)


def gd_step_ref(x, rinv, g, eta):
    """Preconditioned gradient step x - eta * Rinv Rinv^T g (pre-projection).

    The unconstrained Step-3 update of Algorithm 4 (pwGradient); with
    eta = 1/2 this is exactly one IHS iteration with frozen sketch
    (the paper's Theorem 6 equivalence).
    """
    return x - eta * (rinv @ (rinv.T @ g))
