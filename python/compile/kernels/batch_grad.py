"""L1 Pallas kernel: fused mini-batch gradient c = M^T (M x - v).

This is the compute hot-spot of HDpwBatchSGD / HDpwAccBatchSGD (Step 5 of
Algorithm 2): for the sampled row block M = (HDA)_tau and targets
v = (HDb)_tau, compute the stochastic gradient direction. Fusing the
residual matvec and the transposed matvec keeps M resident in VMEM for both
passes (one HBM read of the tile instead of two).

TPU adaptation notes (DESIGN.md section Hardware-Adaptation):
  - grid over row tiles of M: each grid step loads an (rb x d) tile into
    VMEM via BlockSpec, computes the partial M_blk^T (M_blk x - v_blk), and
    accumulates into the (d,) output which stays VMEM-resident across the
    whole grid (index_map constant in the row dimension).
  - both matvecs feed the MXU as (rb x d) x (d,) contractions with
    preferred_element_type matching the accumulator dtype.
  - interpret=True everywhere in this environment: the CPU PJRT plugin
    cannot execute Mosaic custom-calls; numerics are identical.

The `scale` factor (2n/r in the paper) is applied by the L2 wrapper in
model.py, keeping the kernel a pure contraction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _batch_grad_kernel(m_ref, v_ref, x_ref, o_ref):
    """One grid step: accumulate M_blk^T (M_blk x - v_blk) into o_ref."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = m_ref[...]
    x = x_ref[...]
    # residual for this row tile; accumulate in the output dtype
    r = jnp.dot(m, x, preferred_element_type=o_ref.dtype) - v_ref[...]
    o_ref[...] += jnp.dot(m.T, r, preferred_element_type=o_ref.dtype)


def _pick_row_block(r):
    """Largest power-of-two row tile <= r capped at 256 (VMEM budget)."""
    rb = 1
    while rb * 2 <= min(r, 256) and r % (rb * 2) == 0:
        rb *= 2
    return rb


@functools.partial(jax.jit, static_argnames=("row_block",))
def batch_grad(m, v, x, row_block=None):
    """c = M^T (M x - v) with M: (r, d), v: (r,), x: (d,) -> (d,).

    Row-tiled Pallas call; row_block must divide r (defaults to the largest
    power-of-two divisor <= 256).
    """
    r, d = m.shape
    rb = row_block if row_block is not None else _pick_row_block(r)
    assert r % rb == 0, f"row_block {rb} must divide r {r}"
    grid = (r // rb,)
    return pl.pallas_call(
        _batch_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(m, v, x)
