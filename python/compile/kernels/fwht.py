"""L1 Pallas kernel: orthonormal fast Walsh-Hadamard transform (FWHT).

The second preconditioning step of HDpwBatchSGD (Step 2 of Algorithm 2)
multiplies by the Randomized Hadamard Transform HD. H is never materialized:
the kernel runs the O(n log n) butterfly network in-register over a column
panel of the input.

TPU adaptation (DESIGN.md section Hardware-Adaptation): the grid walks column
panels of width `col_block`; each grid step holds an (n x col_block) panel in
VMEM and performs all log2(n) butterfly stages on it before writing back —
one HBM round-trip for the whole transform instead of one per stage (which is
what a naive XLA lowering of the stage-by-stage jnp formulation does). The
butterfly stages are a static Python loop (log2 n is compile-time), each
stage a reshape + add/sub, which Mosaic maps onto VPU lanes.

interpret=True: CPU PJRT cannot run Mosaic custom-calls; numerics identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_kernel(u_ref, o_ref, *, n):
    u = u_ref[...]
    tail = u.shape[1:]
    h = 1
    while h < n:
        u = u.reshape((n // (2 * h), 2, h) + tail)
        a = u[:, 0]
        b = u[:, 1]
        u = jnp.stack([a + b, a - b], axis=1).reshape((n,) + tail)
        h *= 2
    o_ref[...] = u / jnp.sqrt(jnp.asarray(n, dtype=u.dtype))


@functools.partial(jax.jit, static_argnames=("col_block",))
def fwht(u, col_block=None):
    """Orthonormal FWHT along axis 0 of u: (n, d), n a power of two."""
    n, d = u.shape
    assert n & (n - 1) == 0 and n > 0, f"n={n} must be a power of two"
    cb = col_block if col_block is not None else min(d, 128)
    # pad d up to a multiple of cb so the grid tiles exactly
    pad = (-d) % cb
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad)))
    dp = d + pad
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, n=n),
        grid=(dp // cb,),
        in_specs=[pl.BlockSpec((n, cb), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, cb), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, dp), u.dtype),
        interpret=True,
    )(u)
    return out[:, :d] if pad else out
