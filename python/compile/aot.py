"""AOT exporter: lower every L2 graph to HLO text + write the manifest.

This is the ONLY place Python runs in the hdpw stack, and it runs at build
time (`make artifacts`). The Rust coordinator loads the emitted HLO text via
`HloModuleProto::from_text_file` and compiles it on its PJRT CPU client.

Interchange format is HLO *text*, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--n 8192] [--d 32]
"""

import argparse
import functools
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

F64 = jnp.float64
I32 = jnp.int32


def spec(shape, dtype=F64):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(fn, arg_specs):
    """jit -> lower -> stablehlo -> XlaComputation -> HLO text."""
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt):
    return {jnp.float64.dtype: "f64", jnp.int32.dtype: "i32"}[jnp.dtype(dt)]


def build_ops(n, d, rs, chunk_t, pw_t):
    """The artifact manifest: (op name, callable, input specs).

    Shapes are canonical for the e2e example / benches; the Rust runtime
    dispatches on exact (op, shape) match and falls back to the native
    backend otherwise.
    """
    da = d + 1  # packed [A | b]
    ops = []

    def add(name, fn, specs, outputs):
        ops.append(
            {
                "name": name,
                "fn": fn,
                "specs": specs,
                "outputs": outputs,
            }
        )

    # --- elementary ops -----------------------------------------------------
    add(
        f"hd_transform_n{n}_c{da}",
        model.hd_transform,
        [spec((n, da)), spec((n,))],
        1,
    )
    for r in rs:
        add(
            f"batch_grad_r{r}_d{d}",
            model.batch_grad_op,
            [spec((r, d)), spec((r,)), spec((d,)), spec(())],
            1,
        )
    add(
        f"full_grad_n{n}_d{d}",
        model.full_grad,
        [spec((n, d)), spec((n,)), spec((d,))],
        1,
    )
    add(
        f"residual_sq_n{n}_d{d}",
        model.residual_sq,
        [spec((n, d)), spec((n,)), spec((d,))],
        1,
    )
    for cons in ("unc", "l2", "l1"):
        add(
            f"gd_step_{cons}_d{d}",
            functools.partial(model.gd_step, constraint=cons),
            [spec((d,)), spec((d, d)), spec((d,)), spec(()), spec(())],
            1,
        )

    # --- fused solver chunks ------------------------------------------------
    for cons in ("unc", "l2", "l1"):
        for r in rs:
            add(
                f"sgd_chunk_{cons}_n{n}_d{d}_r{r}_t{chunk_t}",
                functools.partial(model.sgd_chunk, constraint=cons),
                [
                    spec((n, d)),            # hda
                    spec((n,)),              # hdb
                    spec((d,)),              # x0
                    spec((d, d)),            # pinv
                    spec((chunk_t, r), I32), # idx
                    spec(()),                # eta
                    spec(()),                # scale
                    spec(()),                # radius
                ],
                2,
            )
        add(
            f"acc_chunk_{cons}_n{n}_d{d}_r{rs[len(rs) // 2]}_t{chunk_t}",
            functools.partial(model.acc_chunk, constraint=cons),
            [
                spec((n, d)),
                spec((n,)),
                spec((d,)),                    # x
                spec((d,)),                    # xhat
                spec((d, d)),                  # pinv
                spec((chunk_t, rs[len(rs) // 2]), I32),
                spec((chunk_t,)),              # alphas
                spec((chunk_t,)),              # qs
                spec((chunk_t,)),              # etas
                spec(()),                      # mu
                spec(()),                      # scale
                spec(()),                      # radius
            ],
            2,
        )
        add(
            f"pw_gradient_chunk_{cons}_n{n}_d{d}_t{pw_t}",
            functools.partial(model.pw_gradient_chunk, T=pw_t, constraint=cons),
            [
                spec((n, d)),
                spec((n,)),
                spec((d,)),
                spec((d, d)),
                spec(()),   # eta
                spec(()),   # radius
            ],
            1,
        )
    return ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--rs", type=int, nargs="+", default=[16, 64, 256])
    ap.add_argument("--chunk-t", type=int, default=50)
    ap.add_argument("--pw-t", type=int, default=10)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    ops = build_ops(args.n, args.d, args.rs, args.chunk_t, args.pw_t)
    manifest = {
        "version": 1,
        "n": args.n,
        "d": args.d,
        "rs": args.rs,
        "chunk_t": args.chunk_t,
        "pw_t": args.pw_t,
        "ops": [],
    }
    for op in ops:
        fname = op["name"] + ".hlo.txt"
        text = to_hlo_text(op["fn"], op["specs"])
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["ops"].append(
            {
                "name": op["name"],
                "file": fname,
                "inputs": [
                    {"shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}
                    for s in op["specs"]
                ],
                "outputs": op["outputs"],
            }
        )
        print(f"lowered {op['name']:48s} -> {fname} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(ops)} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
